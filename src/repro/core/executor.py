"""SmoothCache execution engine.

Runs a diffusion sampler where each step's per-type skip mask comes from a
static `Schedule`.  Because masks are static, each distinct mask compiles to
its own XLA program in which skipped layers are *absent* — the FLOP savings
show up directly in ``compiled.cost_analysis()`` — and the branch cache is
an explicit pytree threaded between steps (so under pjit it inherits the
activation sharding: a cache hit also skips the layer's collectives).

Classifier-free guidance doubles the batch ([cond; uncond]) exactly as in
the paper's DiT-XL protocol; the cache covers both halves.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import diffusion, schedule as schedule_lib
from repro.core.solvers import Solver


def merge_branch_caches(cfg: ModelConfig, computed, old):
    """Fill skipped branches from the previous cache → full-structure cache."""
    out = []
    for si, st in enumerate(cfg.stages):
        stage = []
        comp_stage = computed[si] if computed is not None else None
        for bi, b in enumerate(st.unit):
            comp = comp_stage[bi] if comp_stage is not None else {}
            comp = comp or {}
            d = {}
            for name in b.branch_names():
                if name in comp and comp[name] is not None:
                    d[name] = comp[name]
                else:
                    d[name] = old[si][bi][name]
            stage.append(d)
        out.append(tuple(stage))
    return out


class SmoothCacheExecutor:
    """Owns the per-step jitted model variants (one per distinct skip mask)
    and the sampling loop."""

    def __init__(self, cfg: ModelConfig, solver: Solver, *,
                 cfg_scale: Optional[float] = None, use_flash: bool = False,
                 jit: bool = True):
        assert cfg.task == "diffusion"
        self.cfg = cfg
        self.solver = solver
        self.cfg_scale = cfg_scale
        self.use_flash = use_flash
        self._jit = jit
        self._fns: Dict = {}

    # -- model step ---------------------------------------------------------

    def _model_call(self, params, x, t, label, memory, branch_caches, *,
                    skip, collect):
        """One denoiser evaluation (CFG-doubled when configured)."""
        cfgm = self.cfg
        if self.cfg_scale is not None:
            x2 = jnp.concatenate([x, x], axis=0)
            t2 = jnp.concatenate([t, t], axis=0)
            lab2 = mem2 = None
            if label is not None:
                null = jnp.full_like(label, cfgm.num_classes)
                lab2 = jnp.concatenate([label, null], axis=0)
            if memory is not None:
                mem2 = jnp.concatenate([memory, jnp.zeros_like(memory)], axis=0)
            pred, aux = diffusion.apply(
                cfgm, params, x2, t2, label=lab2, memory=mem2, skip=skip,
                branch_caches=branch_caches, collect_branches=collect,
                use_flash=self.use_flash)
            c, u = jnp.split(pred, 2, axis=0)
            out = u + self.cfg_scale * (c - u)
        else:
            pred, aux = diffusion.apply(
                cfgm, params, x, t, label=label, memory=memory, skip=skip,
                branch_caches=branch_caches, collect_branches=collect,
                use_flash=self.use_flash)
            out = pred
        return out, aux["branch"]

    def _get_fn(self, mask_key, has_cache: bool, collect: bool):
        key = (mask_key, has_cache, collect)
        if key in self._fns:
            return self._fns[key]
        skip = dict(mask_key)

        def fn(params, x, t, label, memory, branch_caches):
            # branch outputs are always collected while caching is active:
            # any computed step may become the cache source for a later one
            pred, computed = self._model_call(
                params, x, t, label, memory,
                branch_caches if has_cache else None,
                skip=skip, collect=True)
            if has_cache:
                cache = merge_branch_caches(self.cfg, computed, branch_caches)
            else:
                cache = computed
            return pred, cache

        if self._jit:
            fn = jax.jit(fn)
        self._fns[key] = fn
        return fn

    def _get_plain_fn(self):
        if "plain" in self._fns:
            return self._fns["plain"]

        def fn(params, x, t, label, memory):
            pred, _ = self._model_call(params, x, t, label, memory, None,
                                       skip=None, collect=False)
            return pred

        if self._jit:
            fn = jax.jit(fn)
        self._fns["plain"] = fn
        return fn

    # -- sampling loop ------------------------------------------------------

    def latent_batch_shape(self, batch):
        return (batch,) + tuple(self.cfg.latent_shape)

    def sample(self, params, key, batch: int, *, schedule=None, label=None,
               memory=None, collect_hook: Optional[Callable] = None,
               return_trajectory: bool = False):
        """Run the full sampler.  ``schedule=None`` → no caching."""
        cfgm = self.cfg
        s_total = self.solver.num_steps
        if schedule is None:
            types = cfgm.layer_types()
            schedule = schedule_lib.no_cache(types, s_total)
        assert schedule.num_steps == s_total
        knoise, kloop = jax.random.split(key)
        x = jax.random.normal(knoise, self.latent_batch_shape(batch))
        state = self.solver.init_state()
        cache = None
        traj = []
        caching_active = (collect_hook is not None or
                          any(v.any() for v in schedule.skip.values()))
        if not caching_active:
            # fast path: plain sampling, no branch collection
            fn = self._get_plain_fn()
            for s in range(s_total):
                t = jnp.full((batch,), self.solver.model_times[s])
                pred = fn(params, x, t, label, memory)
                x, state = self.solver.step(x, pred, s, state,
                                            jax.random.fold_in(kloop, s))
                if return_trajectory:
                    traj.append(x)
            return (x, traj) if return_trajectory else x
        for s in range(s_total):
            mask = schedule.mask_at(s)
            mask_key = tuple(sorted(mask.items()))
            t = jnp.full((batch,), self.solver.model_times[s])
            fn = self._get_fn(mask_key, has_cache=cache is not None,
                              collect=collect_hook is not None)
            pred, cache = fn(params, x, t, label, memory, cache)
            if collect_hook is not None:
                collect_hook(s, cache)
            kstep = jax.random.fold_in(kloop, s)
            x, state = self.solver.step(x, pred, s, state, kstep)
            if return_trajectory:
                traj.append(x)
        return (x, traj) if return_trajectory else x

    def sample_compiled(self, params, key, batch: int, *, schedule=None,
                        label=None, memory=None):
        """Whole-sampler single-jit path: no per-step Python dispatch.
        Compiles once per (schedule identity, batch); use for timing and
        FLOP accounting.  Stochastic solvers get the key threaded in."""
        s_total = self.solver.num_steps
        if schedule is None:
            schedule = schedule_lib.no_cache(self.cfg.layer_types(), s_total)
        # content-addressed compile cache: the canonical JSON string itself is
        # the key (str hash() is process-salted and collides across schedules
        # with equal hashes)
        ck = (schedule.content_key(), batch,
              label is not None, memory is not None)
        if ck not in self._fns:
            fn = self.build_sampler_fn(schedule, batch=batch)
            self._fns[ck] = jax.jit(fn)
        knoise, kloop = jax.random.split(key)
        x = jax.random.normal(knoise, self.latent_batch_shape(batch))
        return self._fns[ck](params, x, label, memory,
                             kloop if self.solver.stochastic else None)

    # -- whole-sampler lowering (for FLOP / roofline accounting) ------------

    def build_sampler_fn(self, schedule, *, batch: int, with_label: bool = False,
                         with_memory: bool = False, mem_len: int = 8):
        """A single jit-able function running all steps with the static
        schedule — ``jax.jit(fn).lower(...)`` exposes total FLOPs/bytes."""
        cfgm = self.cfg
        s_total = self.solver.num_steps

        def fn(params, x, label=None, memory=None, key=None):
            state = self.solver.init_state()
            cache = None
            for s in range(s_total):
                mask = schedule.mask_at(s)
                t = jnp.full((x.shape[0],), self.solver.model_times[s])
                pred, computed = self._model_call(
                    params, x, t, label, memory, cache, skip=mask,
                    collect=True)
                cache = (merge_branch_caches(cfgm, computed, cache)
                         if cache is not None else computed)
                kstep = (jax.random.fold_in(key, s)
                         if key is not None else None)
                x, state = self.solver.step(x, pred, s, state, kstep)
            return x

        return fn
