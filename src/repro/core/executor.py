"""SmoothCache execution engine.

Runs a diffusion sampler where each step's per-type skip mask comes from a
static `Schedule`.  Because masks are static, each distinct mask compiles to
an XLA program in which skipped layers are *absent* — the FLOP savings show
up directly in ``compiled.cost_analysis()`` — and the branch cache is an
explicit pytree threaded between steps (so under pjit it inherits the
activation sharding: a cache hit also skips the layer's collectives).

Three execution paths, in order of increasing ahead-of-time analysis:

* ``sample`` — **eager**: one jitted model call per distinct skip mask,
  Python dispatch every step, every computed branch collected and merged
  into a full-structure cache.  This is the reference path (and the one
  calibration hooks into: it observes *all* branch outputs).
* ``sample_compiled`` — **segmented**: :mod:`repro.core.plan` run-length
  encodes the schedule into constant-mask segments and computes branch
  liveness; one program is compiled per *unique (mask, liveness)
  signature* (= per distinct mask, typically 2–4) and driven with a
  dynamic ``(start, length)`` trip count under ``lax.fori_loop`` (the
  dynamic-length cousin of ``lax.scan``, so segment length/position never
  triggers a recompile), with the solver state threaded through the
  carry.  Types that are never read are never collected nor resident;
  exact per-step liveness is enforced at segment boundaries by dropping
  dead entries.  Latent / solver-state / branch-cache buffers are donated
  so steady-state sampling is allocation-free.
* ``build_sampler_fn`` — **monolith**: all steps unrolled into a single
  jit-able function.  Compile time scales with step count; kept because
  ``jit(fn).lower()`` exposes whole-run FLOPs/bytes for accounting.

Classifier-free guidance doubles the batch ([cond; uncond]) exactly as in
the paper's DiT-XL protocol; the cache covers both halves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import diffusion, plan as plan_lib, schedule as schedule_lib
from repro.core.solvers import Solver


def _rows_finite(x):
    """Per-sample ``isfinite`` reduction of a latent batch: ``(B,)`` bool,
    True where row ``i`` contains no NaN/Inf.  Rows of a batch never mix
    (attention is within-sample, CFG splits per sample), so this is the
    exact poisoned-sample mask — the numerical-health sentinel folded into
    the sampling carries."""
    return jnp.all(jnp.isfinite(x).reshape(x.shape[0], -1), axis=1)


def _take_rows(tree, idx, batch, axis: int = 0):
    """Slice rows ``idx`` out of every batch-shaped leaf of ``tree``
    along ``axis``: dim == ``batch`` → take those rows; == ``2*batch``
    (a CFG-doubled branch cache, ``[cond; uncond]``) → take the rows
    from both halves, keeping the halves contiguous; anything else
    passes through untouched.  Pure gathers — no model compute.
    (Branch-cache leaves carry each stage's scan-stacked repeat axis
    first, so their batch axis is 1; every other carry is batch-first.)"""
    sel = jnp.asarray(np.asarray(idx, np.int32))

    def take(leaf):
        shp = getattr(leaf, "shape", None)
        if shp is not None and len(shp) > axis:
            if shp[axis] == batch:
                return jnp.take(leaf, sel, axis=axis)
            if shp[axis] == 2 * batch:
                return jnp.concatenate(
                    [jnp.take(leaf, sel, axis=axis),
                     jnp.take(leaf, sel + batch, axis=axis)], axis=axis)
        return leaf

    return jax.tree.map(take, tree)


def _concat_rows(trees, batches, axis: int = 0):
    """Concatenate the runs' leaves along the batch ``axis`` — the merge
    dual of :func:`_take_rows`: batch-shaped leaves concat directly,
    CFG-doubled leaves concat all cond halves then all uncond halves;
    non-batch leaves are shared and the first run's value is kept."""
    def dim(leaf):
        shp = tuple(getattr(leaf, "shape", ()))
        return shp[axis] if len(shp) > axis else None

    def cat(*leaves):
        if all(dim(lf) == b for lf, b in zip(leaves, batches)):
            return jnp.concatenate(leaves, axis=axis)
        if all(dim(lf) == 2 * b for lf, b in zip(leaves, batches)):
            cond = [jnp.take(lf, jnp.arange(b), axis=axis)
                    for lf, b in zip(leaves, batches)]
            unc = [jnp.take(lf, jnp.arange(b, 2 * b), axis=axis)
                   for lf, b in zip(leaves, batches)]
            return jnp.concatenate(cond + unc, axis=axis)
        return leaves[0]

    return jax.tree.map(cat, *trees)


def _rescale_structs(structs, old_b: int, new_b: int, axis: int = 1):
    """Remap the batch (or CFG-doubled) dim of the memoized branch
    ``ShapeDtypeStruct`` tree — split/merge rebuilds the donated-buffer
    shapes without re-tracing the model.  Branch structs are stacked
    ``(repeat, batch·{1,2}, ...)``, hence the default ``axis=1``."""
    if structs is None or old_b == new_b:
        return structs

    def re(s):
        shp = list(s.shape)
        if len(shp) > axis and shp[axis] == old_b:
            shp[axis] = new_b
        elif len(shp) > axis and shp[axis] == 2 * old_b:
            shp[axis] = 2 * new_b
        return jax.ShapeDtypeStruct(tuple(shp), s.dtype)

    return jax.tree.map(re, structs)


def merge_branch_caches(cfg: ModelConfig, computed, old):
    """Fill skipped branches from the previous cache → full-structure cache
    (the eager path's collect-everything merge)."""
    out = []
    for si, st in enumerate(cfg.stages):
        stage = []
        comp_stage = computed[si] if computed is not None else None
        for bi, b in enumerate(st.unit):
            comp = comp_stage[bi] if comp_stage is not None else {}
            comp = comp or {}
            d = {}
            for name in b.branch_names():
                if name in comp and comp[name] is not None:
                    d[name] = comp[name]
                else:
                    d[name] = old[si][bi][name]
            stage.append(d)
        out.append(tuple(stage))
    return out


def empty_branch_cache(cfg: ModelConfig):
    """Structure-complete cache pytree with no resident entries."""
    return [tuple({} for _ in st.unit) for st in cfg.stages]


def pruned_branch_caches(cfg: ModelConfig, computed, old, collect, live):
    """Build a post-step cache holding only branches of ``live`` types:
    fresh outputs for ``collect`` types, passed-through entries otherwise.
    Branches outside ``live`` are dropped — with buffer donation their
    storage is reclaimed immediately."""
    collect = set(collect)
    live = set(live)
    out = []
    for si, st in enumerate(cfg.stages):
        comp_stage = computed[si] if computed is not None else None
        stage = []
        for bi, b in enumerate(st.unit):
            comp = (comp_stage[bi] or {}) if comp_stage is not None else {}
            d = {}
            for name, t in zip(b.branch_names(), b.branch_types()):
                if t not in live:
                    continue
                d[name] = comp[name] if t in collect else old[si][bi][name]
            stage.append(d)
        out.append(tuple(stage))
    return out


def prune_cache(cfg: ModelConfig, cache, live):
    """Drop every cache entry whose type is not in ``live`` — a Python-level
    pytree restructure (no device work) applied at segment boundaries."""
    live = set(live)
    out = []
    for si, st in enumerate(cfg.stages):
        stage = []
        for bi, b in enumerate(st.unit):
            types = dict(zip(b.branch_names(), b.branch_types()))
            stage.append({n: v for n, v in cache[si][bi].items()
                          if types[n] in live})
        out.append(tuple(stage))
    return out


def cache_entry_names(cfg: ModelConfig, types) -> List[tuple]:
    """(stage, block, branch_name) triples a cache restricted to ``types``
    must contain — the liveness invariant checked by the segmented loop."""
    ts = set(types)
    out = []
    for si, st in enumerate(cfg.stages):
        for bi, b in enumerate(st.unit):
            for name, t in zip(b.branch_names(), b.branch_types()):
                if t in ts:
                    out.append((si, bi, name))
    return out


@dataclasses.dataclass
class RunState:
    """In-flight state of one segmented sampling run.

    ``start_run`` creates it, ``advance_run`` consumes one plan segment per
    call (the same ops ``sample_with_plan`` performs — that loop *is*
    start + advance-until-done, so a run driven incrementally by a serving
    engine produces bit-identical latents).  With buffer donation enabled
    the previous state's device buffers are reused by the next one: hold
    only the latest ``RunState`` per run.
    """
    x: Any                                   # latent (B, ...)
    state: Any                               # solver state pytree
    cache: Any                               # branch cache (exactly live)
    kloop: Any                               # sampling-loop PRNG key
    plan: plan_lib.ExecutionPlan
    run_index: int                           # next plan.runs entry
    label: Any = None
    memory: Any = None
    structs: Any = None                      # branch ShapeDtypeStructs
    #: (B,) bool device array — per-sample numerical health, carried
    #: through the segment programs (never synced per step; read it at
    #: advance boundaries)
    healthy: Any = None

    @property
    def done(self) -> bool:
        return self.run_index >= len(self.plan.runs)

    @property
    def step(self) -> int:
        """Next sampling step to execute (== num_steps when done)."""
        if self.done:
            return self.plan.num_steps
        return self.plan.runs[self.run_index].start

    @property
    def num_steps(self) -> int:
        return self.plan.num_steps

    #: adaptive runs record realized skip sets; static runs have none
    decisions = None


@dataclasses.dataclass
class AdaptiveRunState:
    """In-flight state of one host-dispatched input-adaptive sampling run
    (per-step granularity: each ``advance_adaptive_run`` call executes one
    decision + model + solver step, exactly the ``sample_adaptive`` loop
    body).  The accumulator/lag decision state lives on device (float32 /
    int32 arrays over ``pool_types``) and is updated by the same
    :func:`~repro.core.calibration.runtime_rule` the fused program inlines;
    only the realized skip *bits* cross to the host — one small
    device→host sync per step, which is exactly what
    :meth:`SmoothCacheExecutor.sample_adaptive_fused` eliminates."""
    x: Any
    state: Any
    cache: Any
    kloop: Any
    step: int                                # next step to execute
    x_prev: Any                              # model input of previous step
    acc: Any                                 # (B, T) f32 per-row est. error
    lag: Any                                 # (B, T) i32 per-row cache age
    decisions: Tuple[tuple, ...]             # realized per-step skip sets
    schedule: Any
    tau: float
    proxy_map: Any
    by_skipset: Dict[frozenset, plan_lib.ProgramSig]
    pool_types: Tuple[str, ...]              # acc/lag/coeff row order
    coeff_a: Any                             # (T,) f32 proxy-map slopes
    coeff_b: Any                             # (T,) f32 proxy-map intercepts
    k_max: int
    label: Any = None
    memory: Any = None
    #: (B,) bool device array — per-sample numerical health (also folds
    #: in the decision accumulator's per-row finiteness)
    healthy: Any = None
    #: (B, T) bool device array — each row's DESIRED skip bits at the
    #: last decided step (None before the first τ>0 decision); the
    #: regroup signature source
    want: Any = None

    @property
    def done(self) -> bool:
        return self.step >= self.schedule.num_steps

    @property
    def num_steps(self) -> int:
        return self.schedule.num_steps

    def row_signatures(self) -> Optional[Tuple[tuple, ...]]:
        """Per-row desired skip sets at the last decided step (tuple of
        sorted type tuples, one per row) — the mask signature a serving
        engine regroups by at boundaries.  One small device→host read;
        None when no per-row decision has been taken yet."""
        if self.want is None:
            return None
        bits = np.asarray(jax.device_get(self.want))
        return tuple(plan_lib.mask_signature(self.pool_types, row)
                     for row in bits)


@dataclasses.dataclass
class FusedAdaptiveRunState:
    """In-flight state of one *fused* adaptive run: everything the
    decision rule touches — latent, previous model input, solver state,
    branch cache, accumulator/lag arrays, and the per-step decision trace
    — is a device array threaded through one donated
    ``lax.fori_loop`` program, so ``advance_adaptive_fused(n_steps)``
    executes a whole step-chunk in a single dispatch with **zero**
    per-step host syncs.  ``decisions`` materializes the trace on the
    host — call it after the run (or chunk), never per step."""
    x: Any
    x_prev: Any                              # model input of previous step
    state: Any
    cache: Any                               # pool-shared structure
    acc: Any                                 # (B, T) f32 per-row est. error
    lag: Any                                 # (B, T) i32 per-row cache age
    trace: Any                               # (S, B, T) bool per-row desires
    kloop: Any
    step: int                                # next step to execute
    schedule: Any
    tau: float
    k_max: int
    table: plan_lib.SwitchTable
    runtime: bool                            # tau > 0: on-device rule
    skip_table: Any                          # (S, T) bool static decisions
    coeff_a: Any                             # (T,) float32
    coeff_b: Any                             # (T,) float32
    label: Any = None
    memory: Any = None
    #: (B,) bool device array — per-sample numerical health, part of the
    #: fused loop carry (acc finiteness folded in), so divergence
    #: detection costs zero extra host syncs
    healthy: Any = None
    #: (S, B) float32 device array of per-row proxy signals, or None —
    #: step telemetry (``start_adaptive_fused_run(telemetry=True)``):
    #: recorded inside the fused loop carry like ``trace``, read only at
    #: the boundaries the host already syncs, so enabling it keeps
    #: ``host_sync_count`` at 0.  Step 0's value is meaningless
    #: (``x_prev`` is zeros before the first step) — report layers mask
    #: it.
    proxy_trace: Any = None

    @property
    def done(self) -> bool:
        return self.step >= self.schedule.num_steps

    @property
    def num_steps(self) -> int:
        return self.schedule.num_steps

    @property
    def pool_types(self) -> Tuple[str, ...]:
        return self.table.types

    @property
    def decisions(self) -> Tuple[tuple, ...]:
        """Realized per-step skip sets of the executed steps (tuple of
        sorted type tuples) — the AND over the trace's per-row desired
        bits, i.e. the masks the batch actually executed.  One
        device→host transfer of the packed bool trace, *not* a per-step
        sync."""
        bits = np.asarray(jax.device_get(self.trace))[:self.step]
        realized = bits.all(axis=1)                    # AND over rows
        return tuple(plan_lib.mask_signature(self.table.types, row)
                     for row in realized)

    def row_signatures(self) -> Optional[Tuple[tuple, ...]]:
        """Per-row desired skip sets at the last executed step (tuple of
        sorted type tuples, one per row) — the mask signature a serving
        engine regroups by at chunk boundaries.  One small device→host
        read of a single trace row (a boundary read, never a per-step
        sync); None before any step has executed."""
        if self.step == 0:
            return None
        bits = np.asarray(jax.device_get(self.trace[self.step - 1]))
        return tuple(plan_lib.mask_signature(self.table.types, row)
                     for row in bits)


class SmoothCacheExecutor:
    """Owns the compiled model/sampler variants (one per plan signature on
    the segmented path, one per distinct skip mask on the eager path) and
    the sampling loops."""

    def __init__(self, cfg: ModelConfig, solver: Solver, *,
                 cfg_scale: Optional[float] = None, use_flash: bool = False,
                 jit: bool = True, donate: Optional[bool] = None):
        assert cfg.task == "diffusion"
        self.cfg = cfg
        self.solver = solver
        self.cfg_scale = cfg_scale
        self.use_flash = use_flash
        self._jit = jit
        # buffer donation is a no-op (with a warning) on CPU, so default it
        # on only where XLA implements input/output aliasing
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self._donate = bool(donate) and jit
        self._fns: Dict = {}
        self._plans: Dict[str, plan_lib.ExecutionPlan] = {}
        self._struct_cache: Dict = {}
        #: per-step device→host decision syncs performed by the
        #: host-dispatched adaptive loop; the fused path never increments
        #: it (asserted by tests and reported by benchmarks)
        self.host_sync_count: int = 0

    @property
    def supports_fused_adaptive(self) -> bool:
        """Whether :meth:`sample_adaptive_fused` is available: the solver
        step must run under ``lax.fori_loop`` (traced index, structure-
        stable state).  Non-scannable solvers (DPM++(3M)) fall back to the
        host-dispatched :meth:`sample_adaptive` loop."""
        return self.solver.scannable

    # -- instrumentation -----------------------------------------------------

    def fn_keys(self, kind: Optional[str] = None):
        """Keys of the compiled-variant table (regression tests assert the
        segmented path builds exactly one ``"seg"`` entry per unique plan
        signature)."""
        keys = list(self._fns)
        if kind is None:
            return keys
        return [k for k in keys
                if isinstance(k, tuple) and k and k[0] == kind]

    def compiled_variant_count(self, kind: Optional[str] = None) -> int:
        return len(self.fn_keys(kind))

    def xla_program_count(self, kind: Optional[str] = None) -> int:
        """Actual XLA executable count behind the variant table: each jitted
        entry holds one compilation per distinct input *shape* (a serving
        engine's batch-size buckets multiply here — the program-budget bound
        is |buckets| × |signatures|).  Falls back to one per entry when the
        jit cache size is not introspectable (non-jit mode, older jax)."""
        total = 0
        for k in self.fn_keys(kind):
            fn = self._fns[k]
            n = None
            cache_size = getattr(fn, "_cache_size", None)
            if callable(cache_size):
                try:
                    n = int(cache_size())
                except Exception:
                    n = None
            total += n if n is not None else 1
        return total

    # -- plan resolution -----------------------------------------------------

    def plan_for(self, schedule) -> plan_lib.ExecutionPlan:
        """Memoized liveness/segmentation analysis of a schedule."""
        ck = schedule.content_key()
        if ck not in self._plans:
            self._plans[ck] = plan_lib.analyze(schedule)
        return self._plans[ck]

    # -- model step ---------------------------------------------------------

    def _model_call(self, params, x, t, label, memory, branch_caches, *,
                    skip, collect):
        """One denoiser evaluation (CFG-doubled when configured).

        ``collect`` is ``True`` (eager/calibration: keep every branch) or a
        collection of layer types (segmented: keep only live branches)."""
        cfgm = self.cfg
        if self.cfg_scale is not None:
            x2 = jnp.concatenate([x, x], axis=0)
            t2 = jnp.concatenate([t, t], axis=0)
            lab2 = mem2 = None
            if label is not None:
                null = jnp.full_like(label, cfgm.num_classes)
                lab2 = jnp.concatenate([label, null], axis=0)
            if memory is not None:
                mem2 = jnp.concatenate([memory, jnp.zeros_like(memory)], axis=0)
            pred, aux = diffusion.apply(
                cfgm, params, x2, t2, label=lab2, memory=mem2, skip=skip,
                branch_caches=branch_caches, collect_branches=collect,
                use_flash=self.use_flash)
            c, u = jnp.split(pred, 2, axis=0)
            out = u + self.cfg_scale * (c - u)
        else:
            pred, aux = diffusion.apply(
                cfgm, params, x, t, label=label, memory=memory, skip=skip,
                branch_caches=branch_caches, collect_branches=collect,
                use_flash=self.use_flash)
            out = pred
        return out, aux["branch"]

    # -- eager per-mask programs --------------------------------------------

    def _get_fn(self, mask_key, has_cache: bool):
        # the eager path always collects every computed branch (any computed
        # step may become the cache source for a later one, and calibration
        # hooks read the full tree) — so `collect` is NOT part of the key:
        # keying on it would compile the same program twice
        key = ("eager", mask_key, has_cache)
        if key in self._fns:
            return self._fns[key]
        skip = dict(mask_key)

        def fn(params, x, t, label, memory, branch_caches):
            pred, computed = self._model_call(
                params, x, t, label, memory,
                branch_caches if has_cache else None,
                skip=skip, collect=True)
            if has_cache:
                cache = merge_branch_caches(self.cfg, computed, branch_caches)
            else:
                cache = computed
            return pred, cache

        if self._jit:
            fn = jax.jit(fn)
        self._fns[key] = fn
        return fn

    def _get_plain_fn(self):
        if "plain" in self._fns:
            return self._fns["plain"]

        def fn(params, x, t, label, memory):
            pred, _ = self._model_call(params, x, t, label, memory, None,
                                       skip=None, collect=False)
            return pred

        if self._jit:
            fn = jax.jit(fn)
        self._fns["plain"] = fn
        return fn

    # -- segmented per-signature programs -----------------------------------

    def _sig_step(self, params, x, t, label, memory, cache, *, skip, collect,
                  live):
        """One plan-driven model evaluation + liveness-pruned cache update:
        skipped branches read the cache, ``collect`` types write fresh
        outputs, and only ``live`` types appear in the output cache."""
        pred, computed = self._model_call(
            params, x, t, label, memory,
            cache if any(skip.values()) else None,
            skip=skip, collect=frozenset(collect))
        new_cache = pruned_branch_caches(self.cfg, computed, cache,
                                         collect, live)
        return pred, new_cache

    def _get_sig_loop_fn(self, sig: plan_lib.ProgramSig):
        """Fused segment program for one signature: model + solver step
        under ``lax.fori_loop`` over a dynamic ``[start, start+length)``
        step range, so a single compilation serves every segment of this
        mask regardless of length or position.  The signature's canonical
        collect set makes the cache pytree a loop invariant (skipped types
        pass through, collected types are overwritten each iteration).
        Latent, solver state, and cache buffers are donated — steady-state
        segments run allocation-free."""
        key = ("seg", sig)
        if key in self._fns:
            return self._fns[key]
        solver = self.solver
        skip, collect, live = sig.skip, sig.collect, sig.structure

        def fn(params, x, state, cache, healthy, start, length, kloop,
               label, memory):
            def body(i, carry):
                x, state, cache, healthy = carry
                t = jnp.full((x.shape[0],), solver.model_times[i])
                pred, cache = self._sig_step(params, x, t, label, memory,
                                             cache, skip=skip,
                                             collect=collect, live=live)
                kstep = (jax.random.fold_in(kloop, i)
                         if solver.stochastic else None)
                x, state = solver.step(x, pred, i, state, kstep)
                # health sentinel rides the carry — no host traffic
                healthy = healthy & _rows_finite(x)
                return (x, state, cache, healthy)

            return jax.lax.fori_loop(start, start + length, body,
                                     (x, state, cache, healthy))

        if self._jit:
            donate = (1, 2, 3, 4) if self._donate else ()
            fn = jax.jit(fn, donate_argnums=donate)
        self._fns[key] = fn
        return fn

    def _get_sig_model_fn(self, sig: plan_lib.ProgramSig):
        """Model-only signature program for non-scannable solvers (e.g.
        DPM++(3M): Python control flow on the step index / state structure).
        The solver step runs eagerly between calls; the cache is donated."""
        key = ("sigstep", sig)
        if key in self._fns:
            return self._fns[key]
        skip, collect, live = sig.skip, sig.collect, sig.structure

        def fn(params, x, t, label, memory, cache):
            return self._sig_step(params, x, t, label, memory, cache,
                                  skip=skip, collect=collect, live=live)

        if self._jit:
            donate = (5,) if self._donate else ()
            fn = jax.jit(fn, donate_argnums=donate)
        self._fns[key] = fn
        return fn

    def _branch_structs(self, params, x, label, memory):
        """ShapeDtypeStructs of every branch-cache entry (one abstract
        trace, memoized per latent shape) — used to build the donated
        placeholder buffers a segment's collect entries start from."""
        key = (x.shape, str(x.dtype), label is not None, memory is not None)
        if key in self._struct_cache:
            return self._struct_cache[key]
        t = jax.ShapeDtypeStruct((x.shape[0],), jnp.float32)
        structs = jax.eval_shape(
            lambda p, xx, tt, lab, mem: self._model_call(
                p, xx, tt, lab, mem, None, skip=None, collect=True)[1],
            params, x, t, label, memory)
        self._struct_cache[key] = structs
        return structs

    def _enter_run_cache(self, cache, sig: plan_lib.ProgramSig, structs):
        """Restructure the (exactly-live) boundary cache into the run's
        loop-invariant structure: pass through the entries the mask reads,
        and add placeholder buffers for the collect entries (their input
        values are never read — the program overwrites them on the first
        iteration, and donation recycles the allocation)."""
        live_in = set(sig.live_in)
        collect = set(sig.collect)
        out = []
        for si, st in enumerate(self.cfg.stages):
            stage = []
            for bi, b in enumerate(st.unit):
                d = {}
                for name, t in zip(b.branch_names(), b.branch_types()):
                    if t in live_in:
                        d[name] = cache[si][bi][name]
                    elif t in collect:
                        s = structs[si][bi][name]
                        d[name] = jnp.zeros(s.shape, s.dtype)
                stage.append(d)
            out.append(tuple(stage))
        return out

    def _get_solver_step(self):
        """Solver step used by the eager loops.  For scannable solvers it is
        jitted with a *traced* step index — the same numeric class XLA uses
        inside the segmented path's fused loop programs (traced-index jit,
        ``fori_loop``, and fused model+solver programs produce identical
        bits; op-by-op eager execution and static-index constant folding do
        not), so eager and segmented sampling stay bit-identical.
        Non-scannable solvers run op-by-op on every path — also
        self-consistent."""
        if "solver_step" in self._fns:
            return self._fns["solver_step"]
        solver = self.solver
        if solver.scannable and self._jit:
            fn = jax.jit(lambda x, pred, s, state, key:
                         solver.step(x, pred, s, state, key))
        else:
            fn = solver.step
        self._fns["solver_step"] = fn
        return fn

    def _get_proxy_fn(self):
        """Relative-L1 change between consecutive model inputs — the
        adaptive path's per-step decision scalar (one reduction over the
        latent, computed before the model call it gates).  The formula is
        shared with calibration (``calibration.rel_l1_change``) so the
        fitted proxy→error maps stay valid at runtime."""
        if "proxy" in self._fns:
            return self._fns["proxy"]
        from repro.core import calibration  # late: calibration is np-heavy
        fn = calibration.rel_l1_change
        if self._jit:
            fn = jax.jit(fn)
        self._fns["proxy"] = fn
        return fn

    def _get_decide_fn(self):
        """One jitted evaluation of the adaptive reuse rule for the
        host-dispatched loop: per-row proxy reduction +
        ``calibration.batch_rule`` — the *same* float32 arithmetic the
        fused program inlines into its loop body, so host and fused
        decision sequences agree bit-for-bit.  Returns ``(want, realized,
        acc', lag')`` with per-sample ``(B, T)`` accumulator state; only
        the realized bits are pulled to the host (the per-step sync the
        fused path removes)."""
        if "decide" in self._fns:
            return self._fns["decide"]
        from repro.core import calibration

        def fn(x, x_prev, acc, lag, a, b, tau, k_max):
            proxy_rows = calibration.rel_l1_change_rows(x, x_prev)
            return calibration.batch_rule(proxy_rows, acc, lag, a, b, tau,
                                          k_max)

        if self._jit:
            fn = jax.jit(fn)
        self._fns["decide"] = fn
        return fn

    def _get_health_fn(self):
        """Boundary health update for the paths whose loop body is not one
        fused program (non-scannable segments, host-dispatched adaptive
        steps): fold the latent's per-row finiteness — and the decision
        accumulator's, when there is one — into the carried flags.  Stays
        on device; nothing syncs here.  (Not a model program: excluded
        from ``MODEL_PROGRAM_KINDS`` and the compile budget.)"""
        if "health" in self._fns:
            return self._fns["health"]

        def fn(healthy, x, acc):
            # acc is per-sample (B, T): a poisoned accumulator row flips
            # only its own flag ((0,)-shaped dummy reduces to scalar True)
            return (healthy & _rows_finite(x)
                    & jnp.all(jnp.isfinite(acc), axis=-1))

        if self._jit:
            fn = jax.jit(fn)
        self._fns["health"] = fn
        return fn

    # -- fused adaptive program ---------------------------------------------

    def _get_fused_fn(self, table: plan_lib.SwitchTable, runtime: bool,
                      telemetry: bool = False):
        """The whole adaptive sampling loop as ONE donated program: proxy
        computation, ``runtime_rule`` over stacked proxy-map coefficients,
        accumulator/lag state carried as device arrays, ``lax.switch``
        over the pool's branch programs (every pool signature shares one
        cache structure, so the carry is uniform by construction), the
        solver step, and a packed bool decision trace — under a
        ``lax.fori_loop`` with a dynamic ``[start, start+length)`` range,
        so one compilation per (batch-shape, pool) signature serves every
        chunk size a serving engine timeslices with.  No value ever
        crosses to the host inside the loop.

        ``runtime=False`` (τ=0) replaces the rule with a lookup into the
        static schedule's precomputed ``skip_table`` — same program
        structure, bit-identical to ``sample_compiled``.

        ``telemetry=True`` additionally records the per-row proxy signal
        into a ``(S, B)`` carry array each step (computed even under
        ``runtime=False``, where the rule itself never reads it).  The
        flag is part of the memo key, so telemetry runs compile their own
        program and non-telemetry programs are untouched; the latent
        arithmetic is identical either way (asserted bit-for-bit by the
        obs bench)."""
        key = ("fused", table, runtime, telemetry)
        if key in self._fns:
            return self._fns[key]
        if not self.solver.scannable:
            raise ValueError(
                f"solver {self.solver.name!r} is not scannable; the fused "
                "adaptive path needs the solver step inside lax.fori_loop "
                "— use sample_adaptive (host dispatch) instead")
        from repro.core import calibration
        solver = self.solver
        types = table.types
        n_types = len(types)
        weights = jnp.asarray([1 << i for i in range(n_types)], jnp.int32)

        def fn(params, x, x_prev, state, cache, acc, lag, trace, healthy,
               proxy_trace, start, length, kloop, label, memory, a, b,
               tau, k_max, skip_table):
            def make_branch(sig):
                def branch(bx, bt, bcache):
                    return self._sig_step(params, bx, bt, label, memory,
                                          bcache, skip=sig.skip,
                                          collect=sig.collect, live=types)
                return branch

            branches = [make_branch(sig) for sig in table.branches]

            def body(s, carry):
                x, x_prev, state, cache, acc, lag, trace, healthy, \
                    proxy_trace = carry
                proxy_rows = None
                if runtime or telemetry:
                    proxy_rows = calibration.rel_l1_change_rows(x, x_prev)
                if runtime:
                    # per-sample rule: each row wants its own skip set from
                    # its own (B, T) acc/lag state; the batch realizes the
                    # AND (one compute refreshes every row's cache)
                    want, bits, acc, lag = calibration.batch_rule(
                        proxy_rows, acc, lag, a, b, tau, k_max,
                        force_compute=(s == 0))
                else:
                    bits = skip_table[s]
                    want = jnp.broadcast_to(bits, acc.shape)
                if telemetry:
                    # step telemetry rides the same carry as the decision
                    # trace: recorded on device, read only at boundaries
                    proxy_trace = proxy_trace.at[s].set(proxy_rows)
                code = (jnp.sum(bits.astype(jnp.int32) * weights)
                        if n_types else jnp.int32(0))
                t = jnp.full((x.shape[0],), solver.model_times[s])
                pred, cache = jax.lax.switch(code, branches, x, t, cache)
                kstep = (jax.random.fold_in(kloop, s)
                         if solver.stochastic else None)
                x_next, state = solver.step(x, pred, s, state, kstep)
                # the trace records per-row DESIRED bits (S, B, T): the
                # executed mask is their AND, and the rows are the regroup
                # signature a serving engine reads at chunk boundaries
                trace = trace.at[s].set(want)
                # health sentinel in the carry: poisoned latents and a
                # runaway/NaN accumulator both flip (only) their row's
                # flag — still zero host syncs inside the loop
                healthy = (healthy & _rows_finite(x_next)
                           & jnp.all(jnp.isfinite(acc), axis=-1))
                return (x_next, x, state, cache, acc, lag, trace, healthy,
                        proxy_trace)

            return jax.lax.fori_loop(
                start, start + length, body,
                (x, x_prev, state, cache, acc, lag, trace, healthy,
                 proxy_trace))

        if self._jit:
            # donate everything the successor state replaces; kloop /
            # label / memory / coefficients are reused across chunks
            donate = (1, 2, 3, 4, 5, 6, 7, 8, 9) if self._donate else ()
            fn = jax.jit(fn, donate_argnums=donate)
        self._fns[key] = fn
        return fn

    # -- sampling loops ------------------------------------------------------

    def latent_batch_shape(self, batch):
        return (batch,) + tuple(self.cfg.latent_shape)

    def initial_latent(self, key, batch: int):
        """The noise-init convention shared by every sampling path:
        ``(x_init, loop_key)`` from one key split.  Calibration uses it to
        reconstruct the model-input trajectory for the proxy signal."""
        knoise, kloop = jax.random.split(key)
        return jax.random.normal(knoise, self.latent_batch_shape(batch)), kloop

    def initial_latent_rows(self, keys, batch: Optional[int] = None):
        """Per-row noise init: row ``i`` is exactly the batch-1
        :meth:`initial_latent` draw of ``keys[i]``, so ANY grouping of the
        rows — one big batch, singletons, or any split/merge in between —
        samples each row bit-identically to its own solo run (XLA keeps
        independent rows bitwise stable across batch shapes; the
        continuous-batching determinism contract rests on this).  The loop
        key is derived from ``keys[0]``; deterministic solvers never read
        it, and stochastic solvers are rejected because their loop-key
        noise IS batch-shape-dependent."""
        keys = list(keys)
        if batch is not None and int(batch) != len(keys):
            raise ValueError(f"row_keys has {len(keys)} entries for "
                             f"batch {batch}")
        if not keys:
            raise ValueError("row_keys must be non-empty")
        if self.solver.stochastic:
            raise ValueError(
                f"solver {self.solver.name!r} is stochastic: its loop-key "
                "noise depends on the batch shape, so per-row keys cannot "
                "make rows batch-invariant — use a single batch key")
        rows, kloop = [], None
        for k in keys:
            x1, kl = self.initial_latent(k, 1)
            if kloop is None:
                kloop = kl
            rows.append(x1)
        return jnp.concatenate(rows, axis=0), kloop

    def sample(self, params, key, batch: int, *, schedule=None, label=None,
               memory=None, collect_hook: Optional[Callable] = None,
               return_trajectory: bool = False):
        """Eager reference sampler.  ``schedule=None`` → no caching."""
        cfgm = self.cfg
        s_total = self.solver.num_steps
        if schedule is None:
            types = cfgm.layer_types()
            schedule = schedule_lib.no_cache(types, s_total)
        assert schedule.num_steps == s_total
        x, kloop = self.initial_latent(key, batch)
        state = self.solver.init_state()
        solver_step = self._get_solver_step()
        cache = None
        traj = []
        caching_active = (collect_hook is not None or
                          any(v.any() for v in schedule.skip.values()))
        if not caching_active:
            # fast path: plain sampling, no branch collection
            fn = self._get_plain_fn()
            for s in range(s_total):
                t = jnp.full((batch,), self.solver.model_times[s])
                pred = fn(params, x, t, label, memory)
                x, state = solver_step(x, pred, s, state,
                                       jax.random.fold_in(kloop, s))
                if return_trajectory:
                    traj.append(x)
            return (x, traj) if return_trajectory else x
        for s in range(s_total):
            mask_key = schedule.mask_key_at(s)
            t = jnp.full((batch,), self.solver.model_times[s])
            fn = self._get_fn(mask_key, has_cache=cache is not None)
            pred, cache = fn(params, x, t, label, memory, cache)
            if collect_hook is not None:
                collect_hook(s, cache)
            kstep = jax.random.fold_in(kloop, s)
            x, state = solver_step(x, pred, s, state, kstep)
            if return_trajectory:
                traj.append(x)
        return (x, traj) if return_trajectory else x

    def start_run(self, params, key, batch: int, *,
                  plan: plan_lib.ExecutionPlan, schedule=None, label=None,
                  memory=None, row_keys=None) -> RunState:
        """Begin a resumable segmented run: validate the plan, draw the
        initial latent, and return a :class:`RunState` positioned before
        the first segment.  Drive it with :meth:`advance_run` — a serving
        engine interleaves several in-flight states this way, and
        ``start + advance-until-done`` is exactly ``sample_with_plan``.

        ``row_keys`` (one PRNG key per row, replaces ``key``) draws each
        row via :meth:`initial_latent_rows`, making the run divisible:
        any :meth:`split_run` / :meth:`merge_runs` regrouping of its rows
        stays bit-identical per row to the rows' solo runs."""
        if plan.num_steps != self.solver.num_steps:
            raise ValueError(f"plan has {plan.num_steps} steps, solver "
                             f"{self.solver.num_steps}")
        if (schedule is not None and plan.schedule_fingerprint is not None
                and plan.schedule_fingerprint
                != plan_lib.schedule_fingerprint(schedule)):
            raise ValueError("plan was analyzed from a different schedule "
                             "(fingerprint mismatch) — re-run plan_for()")
        if row_keys is not None:
            x, kloop = self.initial_latent_rows(row_keys, batch)
        else:
            x, kloop = self.initial_latent(key, batch)
        return RunState(
            x=x, state=self.solver.init_state(),
            cache=empty_branch_cache(self.cfg), kloop=kloop, plan=plan,
            run_index=0, label=label, memory=memory,
            structs=self._branch_structs(params, x, label, memory),
            healthy=jnp.ones((batch,), jnp.bool_))

    def advance_run(self, params, rs: RunState, *,
                    check: bool = False) -> RunState:
        """Advance an in-flight run by one plan segment: enter the
        signature's loop-invariant cache structure, execute the segment's
        steps (fused ``fori_loop`` program, or per-step model programs +
        eager solver for non-scannable solvers), and enforce exact liveness
        at the boundary.  Returns the successor state; with donation the
        input state's buffers are recycled — drop it."""
        if rs.done:
            raise ValueError("run is already complete")
        run = rs.plan.runs[rs.run_index]
        x, state, kloop = rs.x, rs.state, rs.kloop
        label, memory = rs.label, rs.memory
        healthy = rs.healthy
        if healthy is None:                  # pre-sentinel state: assume ok
            healthy = jnp.ones((x.shape[0],), jnp.bool_)
        cache = self._enter_run_cache(rs.cache, run.sig, rs.structs)
        if self.solver.scannable:
            fn = self._get_sig_loop_fn(run.sig)
            x, state, cache, healthy = fn(params, x, state, cache, healthy,
                                          run.start, run.length, kloop,
                                          label, memory)
        else:
            solver_step = self._get_solver_step()
            fn = self._get_sig_model_fn(run.sig)
            for s in range(run.start, run.start + run.length):
                t = jnp.full((x.shape[0],), self.solver.model_times[s])
                pred, cache = fn(params, x, t, label, memory, cache)
                x, state = solver_step(x, pred, s, state,
                                       jax.random.fold_in(kloop, s))
            # NaN/Inf persists in the latent through solver steps, so one
            # boundary check catches any step of the segment (on device,
            # no sync)
            healthy = self._get_health_fn()(healthy, x,
                                            jnp.zeros((0,), jnp.float32))
        # exact liveness at the boundary: entries the next segment does
        # not read are dead — drop them (free: a Python restructure;
        # donation already recycled their buffers)
        cache = prune_cache(self.cfg, cache, run.live_out)
        if check:
            expect = set(cache_entry_names(self.cfg, run.live_out))
            got = {(si, bi, name)
                   for si, stage in enumerate(cache)
                   for bi, d in enumerate(stage)
                   for name in d}
            assert got == expect, (
                f"liveness violation after steps "
                f"[{run.start}, {run.start + run.length}): resident "
                f"{sorted(got)} != live {sorted(expect)}")
        return dataclasses.replace(rs, x=x, state=state, cache=cache,
                                   run_index=rs.run_index + 1,
                                   healthy=healthy)

    def sample_with_plan(self, params, key, batch: int, *,
                         plan: plan_lib.ExecutionPlan, schedule=None,
                         label=None, memory=None, check: bool = False):
        """Segmented sampler: Python dispatch per *segment* (not per step),
        one compiled program per unique plan signature.

        ``check=True`` verifies after every segment that the resident cache
        pytree holds exactly the plan's live entries (the liveness
        invariant: dead branches are provably absent)."""
        rs = self.start_run(params, key, batch, plan=plan, schedule=schedule,
                            label=label, memory=memory)
        while not rs.done:
            rs = self.advance_run(params, rs, check=check)
        return rs.x

    def sample_compiled(self, params, key, batch: int, *, schedule=None,
                        label=None, memory=None, plan=None,
                        check: bool = False):
        """Segmented-plan sampler (the serving hot path): analyzes the
        schedule (memoized, or pass a pre-analyzed ``plan`` from a
        :class:`~repro.cache.artifact.CacheArtifact`) and compiles one
        program per unique (mask, liveness) signature — not per step, not
        one monolith."""
        if schedule is None:
            schedule = schedule_lib.no_cache(self.cfg.layer_types(),
                                             self.solver.num_steps)
        if plan is None:
            plan = self.plan_for(schedule)
        return self.sample_with_plan(params, key, batch, plan=plan,
                                     schedule=schedule, label=label,
                                     memory=memory, check=check)

    # -- input-adaptive runtime dispatch ------------------------------------

    def sample_adaptive(self, params, key, batch: int, *, schedule,
                        tau: float, proxy_map=None, pool=None, k_max: int = 3,
                        label=None, memory=None,
                        return_decisions: bool = False):
        """Input-adaptive sampler: per-step reuse decisions dispatched over
        the precompiled mask-lattice pool.

        ``schedule`` is the offline (static) base schedule: it defines the
        candidate pool (:func:`repro.core.plan.mask_lattice` over its
        ever-skipped types) and is followed verbatim when ``tau == 0``.
        With ``tau > 0`` the runtime rule takes over: before each model
        call the proxy signal (relative L1 change of the latent) is mapped
        through the calibrated ``proxy_map`` to a per-type error estimate;
        a type is reused while the error accumulated since its last compute
        stays under ``tau`` and the cache age stays ≤ ``k_max``, and is
        recomputed (resetting the accumulator) otherwise.

        Every decision selects a signature from the pool, so at most
        ``len(pool)`` programs are ever compiled (2^|ever-skipped|,
        typically 4) — never one per step.  All pool signatures share one
        cache structure (the ever-skipped type set), so per-step dispatch
        needs no cache restructuring; the per-signature programs are the
        same ``"sigstep"`` table entries the non-scannable segmented path
        uses, and the solver step runs through the same traced-index jit as
        the eager path, so ``tau=0`` reproduces ``sample_compiled`` on the
        same schedule bit-identically.

        ``return_decisions=True`` additionally returns the realized
        per-step skip sets (tuple of sorted type tuples) for accounting.
        """
        rs = self.start_adaptive_run(
            params, key, batch, schedule=schedule, tau=tau,
            proxy_map=proxy_map, pool=pool, k_max=k_max, label=label,
            memory=memory)
        while not rs.done:
            rs = self.advance_adaptive_run(params, rs)
        if return_decisions:
            return rs.x, rs.decisions
        return rs.x

    def _adaptive_setup(self, schedule, tau, proxy_map, pool, k_max):
        """Shared validation + pool derivation for both adaptive paths.
        Returns ``(schedule, tau, pool, by_skipset, pool_types,
        coeff_a, coeff_b)`` with the proxy-map coefficients stacked into
        the device representation (zeros when τ=0 never evaluates them)."""
        s_total = self.solver.num_steps
        if schedule is None:
            schedule = schedule_lib.no_cache(self.cfg.layer_types(), s_total)
        if schedule.num_steps != s_total:
            raise ValueError(f"schedule has {schedule.num_steps} steps, "
                             f"solver {s_total}")
        tau = float(tau)
        if tau < 0:
            raise ValueError(f"tau must be >= 0, got {tau}")
        if int(k_max) < 1:
            raise ValueError(
                f"adaptive k_max must be >= 1, got {k_max} — k_max=0 "
                "would compile the whole candidate pool yet never reuse "
                "a cache entry (silently behaving like no_cache)")
        if tau > 0 and proxy_map is None:
            raise ValueError(
                "sample_adaptive with tau > 0 needs a calibrated proxy_map "
                "(calibrate the adaptive policy or load its artifact)")
        if pool is None:
            pool = plan_lib.mask_lattice(schedule)
        by_skipset = plan_lib.pool_index(pool)
        pool_live = frozenset().union(*by_skipset) if by_skipset else \
            frozenset()
        pool_types = tuple(sorted(pool_live))
        if tau > 0:
            try:
                a, b = proxy_map.stacked(pool_types)
            except KeyError as e:
                # keep the adaptive misconfiguration contract: every
                # invalid-parameter path out of here is a ValueError
                raise ValueError(f"proxy_map lacks coefficients for the "
                                 f"candidate pool — recalibrate: {e}")
            coeff_a, coeff_b = jnp.asarray(a), jnp.asarray(b)
        else:
            zeros = np.zeros((len(pool_types),), np.float32)
            coeff_a = coeff_b = jnp.asarray(zeros)
        return schedule, tau, pool, by_skipset, pool_types, coeff_a, coeff_b

    def start_adaptive_run(self, params, key, batch: int, *, schedule,
                           tau: float, proxy_map=None, pool=None,
                           k_max: int = 3, label=None,
                           memory=None, row_keys=None) -> AdaptiveRunState:
        """Begin a resumable host-dispatched adaptive run: validate the
        decision parameters, derive/index the candidate pool, and enter the
        pool's shared cache structure.  Drive it with
        :meth:`advance_adaptive_run` (one step per call);
        ``start + advance-until-done`` is exactly :meth:`sample_adaptive`.
        ``row_keys`` draws per-row initial latents (see :meth:`start_run`)
        so the run can be split/merged bit-identically per row."""
        schedule, tau, pool, by_skipset, pool_types, coeff_a, coeff_b = \
            self._adaptive_setup(schedule, tau, proxy_map, pool, k_max)
        n_types = len(pool_types)
        if row_keys is not None:
            x, kloop = self.initial_latent_rows(row_keys, batch)
        else:
            x, kloop = self.initial_latent(key, batch)
        structs = self._branch_structs(params, x, label, memory)
        # every pool signature shares the same structure; enter once with
        # placeholder buffers for all ever-skipped types
        cache = self._enter_run_cache(empty_branch_cache(self.cfg),
                                      by_skipset[frozenset()], structs)
        return AdaptiveRunState(
            x=x, state=self.solver.init_state(), cache=cache, kloop=kloop,
            step=0, x_prev=None,
            acc=jnp.zeros((batch, n_types), jnp.float32),
            lag=jnp.zeros((batch, n_types), jnp.int32),
            decisions=(), schedule=schedule, tau=tau, proxy_map=proxy_map,
            by_skipset=by_skipset, pool_types=pool_types,
            coeff_a=coeff_a, coeff_b=coeff_b, k_max=int(k_max),
            label=label, memory=memory,
            healthy=jnp.ones((batch,), jnp.bool_))

    def advance_adaptive_run(self, params,
                             rs: AdaptiveRunState) -> AdaptiveRunState:
        """Advance an in-flight adaptive run by one step: evaluate the
        decision rule on device (shared with the fused path), pull the
        skip *bits* to the host — the one per-step sync this path pays —
        dispatch the matching precompiled pool program, and run the solver
        step.  Returns the successor state; with donation the input
        state's cache buffers are recycled — drop it."""
        if rs.done:
            raise ValueError("run is already complete")
        s = rs.step
        x, schedule, tau = rs.x, rs.schedule, rs.tau
        acc, lag, want = rs.acc, rs.lag, rs.want
        if s == 0:
            skipset = frozenset()           # cache is empty: compute all
        elif tau == 0.0:
            # trust the offline schedule verbatim (bit-identical to
            # sample_compiled on the same schedule)
            skipset = frozenset(t for t, sk in schedule.mask_key_at(s)
                                if sk)
        else:
            want, realized_dev, acc, lag = self._get_decide_fn()(
                x, rs.x_prev, rs.acc, rs.lag, rs.coeff_a, rs.coeff_b,
                tau, rs.k_max)
            bits = np.asarray(jax.device_get(realized_dev))
            self.host_sync_count += 1       # the per-step device→host sync
            skipset = frozenset(t for t, hit in zip(rs.pool_types, bits)
                                if hit)
        sig = rs.by_skipset.get(skipset)
        if sig is None:
            raise ValueError(
                f"static schedule mask at step {s} skips "
                f"{sorted(skipset)}, absent from the candidate pool — "
                "derive the pool from this schedule via mask_lattice()")
        t_arr = jnp.full((x.shape[0],), self.solver.model_times[s])
        fn = self._get_sig_model_fn(sig)
        pred, cache = fn(params, x, t_arr, rs.label, rs.memory, rs.cache)
        x_next, state = self._get_solver_step()(
            x, pred, s, rs.state, jax.random.fold_in(rs.kloop, s))
        healthy = rs.healthy
        if healthy is None:                  # pre-sentinel state: assume ok
            healthy = jnp.ones((x.shape[0],), jnp.bool_)
        # on-device fold — does NOT join the per-step decision sync above
        healthy = self._get_health_fn()(healthy, x_next, acc)
        return dataclasses.replace(
            rs, x=x_next, state=state, cache=cache, step=s + 1, x_prev=x,
            acc=acc, lag=lag, want=want, healthy=healthy,
            decisions=rs.decisions + (tuple(sorted(skipset)),))

    # -- fused adaptive sampling (decision + dispatch on device) -------------

    def sample_adaptive_fused(self, params, key, batch: int, *, schedule,
                              tau: float, proxy_map=None, pool=None,
                              k_max: int = 3, label=None, memory=None,
                              return_decisions: bool = False):
        """Input-adaptive sampler fused into a single donated program:
        the entire loop — proxy computation, ``runtime_rule`` over the
        proxy map's stacked coefficients, accumulator/lag carry, and
        ``lax.switch`` dispatch over the pool's branch programs — runs on
        device, with **zero** per-step host syncs and exactly one
        compiled program per (batch-shape, pool) signature (vs pool-size
        programs × per-step dispatches on :meth:`sample_adaptive`).

        Decision sequences are bit-identical to :meth:`sample_adaptive`
        (both evaluate :func:`~repro.core.calibration.runtime_rule` in
        float32 on device), and at ``tau=0`` the whole run is
        bit-identical to :meth:`sample_compiled` on the same schedule.
        Requires a scannable solver — see :attr:`supports_fused_adaptive`.

        ``return_decisions=True`` additionally returns the realized
        per-step skip sets, materialized from the device-side decision
        trace after the run (one transfer, not per step)."""
        rs = self.start_adaptive_fused_run(
            params, key, batch, schedule=schedule, tau=tau,
            proxy_map=proxy_map, pool=pool, k_max=k_max, label=label,
            memory=memory)
        rs = self.advance_adaptive_fused(params, rs)
        if return_decisions:
            return rs.x, rs.decisions
        return rs.x

    def _fused_setup(self, schedule, tau, proxy_map, pool, k_max):
        """Shared derivation for the fused start + snapshot-import paths:
        validates the solver, runs :meth:`_adaptive_setup`, builds the
        ``lax.switch`` branch table, and materializes the static
        ``skip_table`` (τ=0) or its shape-stable runtime dummy (τ>0).
        Returns ``(schedule, tau, table, runtime, skip_table, coeff_a,
        coeff_b)`` — all deterministic functions of the entry parameters,
        which is what makes a restored run's continuation bit-identical
        to the original's."""
        if not self.supports_fused_adaptive:
            raise ValueError(
                f"solver {self.solver.name!r} is not scannable; the fused "
                "adaptive path needs the solver step inside lax.fori_loop "
                "— use sample_adaptive (host dispatch) instead")
        schedule, tau, pool, by_skipset, pool_types, coeff_a, coeff_b = \
            self._adaptive_setup(schedule, tau, proxy_map, pool, k_max)
        table = plan_lib.switch_branch_table(pool)
        s_total = schedule.num_steps
        n_types = len(table.types)
        runtime = tau > 0
        if runtime:
            # the rule only ever selects subsets of the pool types; the
            # static table is never read — pass a shape-stable dummy
            skip_table = jnp.zeros((1, n_types), jnp.bool_)
        else:
            cols = [np.asarray(schedule.skip[t], bool) for t in table.types]
            skip_table = (np.stack(cols, axis=1) if cols
                          else np.zeros((s_total, 0), bool))
            for s in range(s_total):
                skipset = frozenset(t for t, sk in schedule.mask_key_at(s)
                                    if sk)
                if skipset not in by_skipset:
                    raise ValueError(
                        f"static schedule mask at step {s} skips "
                        f"{sorted(skipset)}, absent from the candidate "
                        "pool — derive the pool from this schedule via "
                        "mask_lattice()")
            skip_table = jnp.asarray(skip_table)
        return schedule, tau, table, runtime, skip_table, coeff_a, coeff_b

    def start_adaptive_fused_run(self, params, key, batch: int, *,
                                 schedule, tau: float, proxy_map=None,
                                 pool=None, k_max: int = 3, label=None,
                                 memory=None, row_keys=None,
                                 telemetry: bool = False
                                 ) -> FusedAdaptiveRunState:
        """Begin a resumable fused adaptive run.  Drive it with
        :meth:`advance_adaptive_fused` — a serving engine timeslices with
        ``n_steps`` chunks, each a single program dispatch.  ``row_keys``
        draws per-row initial latents (see :meth:`start_run`) so the run
        can be split/merged bit-identically per row.  ``telemetry=True``
        additionally records the per-row proxy signal into the loop carry
        (``rs.proxy_trace``) for per-request
        :class:`repro.obs.CacheReport` explainers — still zero per-step
        host syncs, and the latent bits are unchanged (the telemetry
        program differs only in the extra carry writes)."""
        schedule, tau, table, runtime, skip_table, coeff_a, coeff_b = \
            self._fused_setup(schedule, tau, proxy_map, pool, k_max)
        s_total = schedule.num_steps
        n_types = len(table.types)
        if row_keys is not None:
            x, kloop = self.initial_latent_rows(row_keys, batch)
        else:
            x, kloop = self.initial_latent(key, batch)
        structs = self._branch_structs(params, x, label, memory)
        cache = self._enter_run_cache(empty_branch_cache(self.cfg),
                                      table.branches[0], structs)
        return FusedAdaptiveRunState(
            x=x, x_prev=jnp.zeros_like(x), state=self.solver.init_state(),
            cache=cache,
            acc=jnp.zeros((batch, n_types), jnp.float32),
            lag=jnp.zeros((batch, n_types), jnp.int32),
            trace=jnp.zeros((s_total, batch, n_types), jnp.bool_),
            kloop=kloop, step=0, schedule=schedule, tau=tau,
            k_max=int(k_max), table=table, runtime=runtime,
            skip_table=skip_table, coeff_a=coeff_a, coeff_b=coeff_b,
            label=label, memory=memory,
            healthy=jnp.ones((batch,), jnp.bool_),
            proxy_trace=(jnp.zeros((s_total, batch), jnp.float32)
                         if telemetry else None))

    def advance_adaptive_fused(self, params, rs: FusedAdaptiveRunState,
                               n_steps: Optional[int] = None
                               ) -> FusedAdaptiveRunState:
        """Advance an in-flight fused run by ``n_steps`` sampling steps
        (default: all remaining) in ONE program dispatch — the dynamic
        ``(start, length)`` trip count means chunk size never triggers a
        recompile, so a serving engine can timeslice adaptive runs
        without per-step host round-trips.  Returns the successor state;
        with donation the input state's buffers are recycled — drop it."""
        if rs.done:
            raise ValueError("run is already complete")
        remaining = rs.num_steps - rs.step
        length = remaining if n_steps is None else min(int(n_steps),
                                                       remaining)
        if length < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        telemetry = rs.proxy_trace is not None
        fn = self._get_fused_fn(rs.table, rs.runtime, telemetry)
        healthy = rs.healthy
        if healthy is None:                  # pre-sentinel state: assume ok
            healthy = jnp.ones((rs.x.shape[0],), jnp.bool_)
        # telemetry-off runs carry a shape-stable dummy through the loop
        # (the program never touches it; the memo key separates variants)
        proxy_trace = (rs.proxy_trace if telemetry
                       else jnp.zeros((0, 0), jnp.float32))
        x, x_prev, state, cache, acc, lag, trace, healthy, proxy_trace = \
            fn(params, rs.x, rs.x_prev, rs.state, rs.cache, rs.acc,
               rs.lag, rs.trace, healthy, proxy_trace, rs.step, length,
               rs.kloop, rs.label, rs.memory, rs.coeff_a, rs.coeff_b,
               rs.tau, rs.k_max, rs.skip_table)
        return dataclasses.replace(
            rs, x=x, x_prev=x_prev, state=state, cache=cache, acc=acc,
            lag=lag, trace=trace, step=rs.step + length, healthy=healthy,
            proxy_trace=proxy_trace if telemetry else None)

    # -- run-state split / merge (continuous batching) ------------------------

    #: per-kind fields holding per-row (or CFG-doubled) device carries,
    #: with each field's batch axis — branch caches are scan-stacked
    #: ``(repeat, batch·{1,2}, ...)`` so their batch axis is 1; everything
    #: else in a run state is shared across rows
    _ROW_FIELDS = {
        RunState: (("x", 0), ("state", 0), ("cache", 1), ("label", 0),
                   ("memory", 0), ("healthy", 0)),
        AdaptiveRunState: (("x", 0), ("state", 0), ("cache", 1),
                           ("label", 0), ("memory", 0), ("healthy", 0),
                           ("x_prev", 0), ("acc", 0), ("lag", 0),
                           ("want", 0)),
        FusedAdaptiveRunState: (("x", 0), ("state", 0), ("cache", 1),
                                ("label", 0), ("memory", 0),
                                ("healthy", 0), ("x_prev", 0), ("acc", 0),
                                ("lag", 0)),
    }

    @property
    def supports_split(self) -> bool:
        """Whether run states are divisible values (:meth:`split_run` /
        :meth:`merge_runs`): requires a deterministic solver — a
        stochastic solver's loop-key noise depends on the batch shape, so
        its rows are not batch-invariant."""
        return not self.solver.stochastic

    def _check_split(self, rs):
        if not self.supports_split:
            raise ValueError(
                f"solver {self.solver.name!r} is stochastic: run states "
                "are not divisible (loop-key noise is batch-shape-"
                "dependent, so split rows would diverge from their batch)")
        fields = self._ROW_FIELDS.get(type(rs))
        if fields is None:
            raise ValueError(
                f"not a divisible run state: {type(rs).__name__}")
        return fields

    def split_run(self, rs, groups) -> List[Any]:
        """Split one in-flight run into independent sub-runs over disjoint
        row groups — pure carry slicing along the batch axis (gathers
        only, no model compute), bit-identical per row: XLA keeps
        independent rows bitwise stable across batch shapes, so each
        sub-run advances exactly as its rows would have in the original
        batch.  τ>0 adaptive sub-runs carry their per-sample ``(B, T)``
        acc/lag rows with them and realize their OWN mask AND from the
        split point on — the per-sample-mask property boundary regroup
        exploits.  Rows not covered by any group are dropped (how
        per-row retry discards a poisoned sample).  Landing only on
        existing bucket shapes is the caller's job — the serving engine
        splits to power-of-two sizes so ``xla_program_count`` never
        grows."""
        fields = self._check_split(rs)
        batch = int(rs.x.shape[0])
        groups = [tuple(int(i) for i in g) for g in groups]
        if not groups:
            raise ValueError("split_run needs at least one row group")
        seen = set()
        for g in groups:
            if not g:
                raise ValueError("split groups must be non-empty")
            for i in g:
                if not 0 <= i < batch:
                    raise ValueError(
                        f"row index {i} out of range for batch {batch}")
                if i in seen:
                    raise ValueError(f"row index {i} appears in two groups")
                seen.add(i)
        out = []
        for g in groups:
            upd = {f: _take_rows(getattr(rs, f), g, batch, axis=ax)
                   for f, ax in fields}
            if isinstance(rs, RunState):
                upd["structs"] = _rescale_structs(rs.structs, batch, len(g))
            elif isinstance(rs, FusedAdaptiveRunState):
                sel = jnp.asarray(np.asarray(g, np.int32))
                upd["trace"] = jnp.take(rs.trace, sel, axis=1)
                if rs.proxy_trace is not None:
                    upd["proxy_trace"] = jnp.take(rs.proxy_trace, sel,
                                                  axis=1)
            out.append(dataclasses.replace(rs, **upd))
        return out

    def merge_runs(self, runs) -> Any:
        """Merge position-aligned sub-runs into one batch — the concat
        dual of :meth:`split_run`, bit-identical per row.  Runs must be
        of the same kind at the same position with the same execution
        parameters (same plan + segment index, or same schedule/τ/k_max/
        pool); per-row carries concatenate, shared parameters come from
        the first run.  From the merge point on, τ>0 adaptive decisions
        realize the AND over the union's rows — each row's acc/lag rows
        merge untouched, so no accumulated-error history is lost."""
        runs = list(runs)
        if not runs:
            raise ValueError("merge_runs needs at least one run")
        r0 = runs[0]
        fields = self._check_split(r0)
        if len(runs) == 1:
            return r0
        if any(type(r) is not type(r0) for r in runs[1:]):
            raise ValueError("cannot merge runs of different kinds")
        batches = [int(r.x.shape[0]) for r in runs]
        if isinstance(r0, RunState):
            for r in runs[1:]:
                if r.plan is not r0.plan and r.plan != r0.plan:
                    raise ValueError(
                        "cannot merge runs with different plans")
                if r.run_index != r0.run_index:
                    raise ValueError(
                        "cannot merge runs at different segments")
        else:
            for r in runs[1:]:
                if (r.schedule.content_key() != r0.schedule.content_key()
                        or r.tau != r0.tau or r.k_max != r0.k_max):
                    raise ValueError(
                        "cannot merge adaptive runs with different "
                        "schedule/tau/k_max")
                if r.step != r0.step:
                    raise ValueError(
                        "cannot merge adaptive runs at different steps")
            if isinstance(r0, AdaptiveRunState):
                if any(r.pool_types != r0.pool_types for r in runs[1:]):
                    raise ValueError(
                        "cannot merge runs over different pools")
            elif any(r.table is not r0.table and r.table != r0.table
                     for r in runs[1:]):
                raise ValueError("cannot merge runs over different pools")
        upd = {f: _concat_rows([getattr(r, f) for r in runs], batches,
                               axis=ax)
               for f, ax in fields}
        if isinstance(r0, RunState):
            upd["structs"] = _rescale_structs(r0.structs, batches[0],
                                              sum(batches))
        elif isinstance(r0, AdaptiveRunState):
            # split siblings share one realized history; a join brings a
            # different one — drop to the honest "no per-step record"
            # value rather than claim one side's history for all rows
            if any(r.decisions != r0.decisions for r in runs[1:]):
                upd["decisions"] = ()
        else:
            # per-row desired traces concat exactly; `decisions` (the AND
            # over rows) becomes conservative for pre-merge steps
            upd["trace"] = jnp.concatenate([r.trace for r in runs], axis=1)
            if all(r.proxy_trace is not None for r in runs):
                upd["proxy_trace"] = jnp.concatenate(
                    [r.proxy_trace for r in runs], axis=1)
            elif any(r.proxy_trace is not None for r in runs):
                # mixed telemetry: no honest merged trace exists
                upd["proxy_trace"] = None
        return dataclasses.replace(r0, **upd)

    # -- run-state snapshot seams (durable serving) ---------------------------

    @property
    def supports_export(self) -> bool:
        """Whether run states can cross a process boundary via
        :meth:`export_run` / :meth:`import_run` — true for all three run
        kinds of this executor (the durable layer checks the attribute so
        test fakes opt in explicitly)."""
        return True

    def export_run(self, rs) -> Tuple[str, Dict, Dict]:
        """Run state → ``(kind, arrays, static)``, the snapshot seam of
        the durable serving layer.  ``arrays`` is a pytree of device
        arrays (serializable host-side by ``repro.checkpoint.io``);
        ``static`` is the small JSON-safe position/parameter stamp needed
        to rebuild the rest.  Derived Python objects — plan, schedule,
        pool index, switch table, cache structs — are deliberately NOT
        exported: :meth:`import_run` rebuilds them from the serving
        entry, and the caller's provenance stamp (entry name/version,
        schedule fingerprint, plan hash) is what guarantees it rebuilds
        the *same* ones.  Reading the arrays is a boundary transfer the
        host was already allowed to make — never a per-step sync, so a
        fused run's ``host_sync_count`` stays untouched."""
        if isinstance(rs, RunState):
            arrays = {"x": rs.x, "state": rs.state, "cache": rs.cache,
                      "kloop": rs.kloop, "label": rs.label,
                      "memory": rs.memory, "healthy": rs.healthy}
            static = {"batch": int(rs.x.shape[0]),
                      "run_index": int(rs.run_index)}
            return "plan", arrays, static
        if isinstance(rs, AdaptiveRunState):
            arrays = {"x": rs.x, "state": rs.state, "cache": rs.cache,
                      "kloop": rs.kloop, "label": rs.label,
                      "memory": rs.memory, "healthy": rs.healthy,
                      "x_prev": rs.x_prev, "acc": rs.acc, "lag": rs.lag,
                      "want": rs.want}
            static = {"batch": int(rs.x.shape[0]), "step": int(rs.step),
                      "tau": float(rs.tau), "k_max": int(rs.k_max),
                      "decisions": [list(d) for d in rs.decisions]}
            return "adaptive", arrays, static
        if isinstance(rs, FusedAdaptiveRunState):
            arrays = {"x": rs.x, "state": rs.state, "cache": rs.cache,
                      "kloop": rs.kloop, "label": rs.label,
                      "memory": rs.memory, "healthy": rs.healthy,
                      "x_prev": rs.x_prev, "acc": rs.acc, "lag": rs.lag,
                      "trace": rs.trace, "proxy_trace": rs.proxy_trace}
            static = {"batch": int(rs.x.shape[0]), "step": int(rs.step),
                      "tau": float(rs.tau), "k_max": int(rs.k_max)}
            return "adaptive_fused", arrays, static
        raise ValueError(
            f"not an exportable run state: {type(rs).__name__}")

    def import_run(self, params, kind: str, arrays: Dict, static: Dict, *,
                   plan=None, schedule=None, tau: float = 0.0,
                   proxy_map=None, pool=None, k_max: int = 3):
        """``(kind, arrays, static)`` → run state, the inverse of
        :meth:`export_run`.  The entry-side parameters (``plan`` /
        ``schedule`` / ``tau`` / ``proxy_map`` / ``pool`` / ``k_max``)
        come from the serving entry the run launched under; every derived
        structure is rebuilt exactly as the matching ``start_*`` would
        build it, so advancing the restored state is bit-identical to
        advancing the original.  Parameter disagreements between the
        snapshot stamp and the entry are refused (``ValueError``), not
        absorbed — the caller quarantines and replays from start."""
        label = arrays.get("label")
        memory = arrays.get("memory")
        healthy = arrays.get("healthy")
        if kind == "plan":
            if plan is None:
                raise ValueError(
                    "import_run kind='plan' needs the plan= the run was "
                    "launched with")
            run_index = int(static["run_index"])
            if not 0 <= run_index <= len(plan.runs):
                raise ValueError(
                    f"snapshot run_index {run_index} out of range for a "
                    f"{len(plan.runs)}-segment plan — wrong plan?")
            x = arrays["x"]
            return RunState(
                x=x, state=arrays["state"], cache=arrays["cache"],
                kloop=arrays["kloop"], plan=plan, run_index=run_index,
                label=label, memory=memory,
                structs=self._branch_structs(params, x, label, memory),
                healthy=healthy)
        if kind not in ("adaptive", "adaptive_fused"):
            raise ValueError(f"unknown run kind {kind!r}")
        # defense in depth: the stamp's decision parameters must equal the
        # entry's — a drifted τ/k_max would silently change every decision
        # from the restore point on
        if float(static.get("tau", tau)) != float(tau) \
                or int(static.get("k_max", k_max)) != int(k_max):
            raise ValueError(
                f"snapshot tau/k_max ({static.get('tau')}/"
                f"{static.get('k_max')}) disagree with the serving entry "
                f"({float(tau)}/{int(k_max)})")
        step = int(static["step"])
        if kind == "adaptive":
            schedule, tau, pool, by_skipset, pool_types, coeff_a, \
                coeff_b = self._adaptive_setup(schedule, tau, proxy_map,
                                               pool, k_max)
            if step > schedule.num_steps:
                raise ValueError(
                    f"snapshot step {step} exceeds the schedule's "
                    f"{schedule.num_steps} steps — wrong schedule?")
            return AdaptiveRunState(
                x=arrays["x"], state=arrays["state"],
                cache=arrays["cache"], kloop=arrays["kloop"], step=step,
                x_prev=arrays.get("x_prev"), acc=arrays["acc"],
                lag=arrays["lag"],
                decisions=tuple(tuple(d)
                                for d in static.get("decisions", ())),
                schedule=schedule, tau=tau, proxy_map=proxy_map,
                by_skipset=by_skipset, pool_types=pool_types,
                coeff_a=coeff_a, coeff_b=coeff_b, k_max=int(k_max),
                label=label, memory=memory, healthy=healthy,
                want=arrays.get("want"))
        schedule, tau, table, runtime, skip_table, coeff_a, coeff_b = \
            self._fused_setup(schedule, tau, proxy_map, pool, k_max)
        if step > schedule.num_steps:
            raise ValueError(
                f"snapshot step {step} exceeds the schedule's "
                f"{schedule.num_steps} steps — wrong schedule?")
        return FusedAdaptiveRunState(
            x=arrays["x"], x_prev=arrays["x_prev"], state=arrays["state"],
            cache=arrays["cache"], acc=arrays["acc"], lag=arrays["lag"],
            trace=arrays["trace"], kloop=arrays["kloop"], step=step,
            schedule=schedule, tau=tau, k_max=int(k_max), table=table,
            runtime=runtime, skip_table=skip_table, coeff_a=coeff_a,
            coeff_b=coeff_b, label=label, memory=memory, healthy=healthy,
            proxy_trace=arrays.get("proxy_trace"))

    # -- whole-sampler lowering (for FLOP / roofline accounting) ------------

    def build_sampler_fn(self, schedule):
        """A single jit-able function unrolling all steps of the (liveness-
        pruned) plan — ``jax.jit(fn).lower(...)`` exposes total FLOPs/bytes.
        Compile time scales with step count; use ``sample_compiled`` for
        actual sampling."""
        s_total = self.solver.num_steps
        plan = self.plan_for(schedule)

        def fn(params, x, label=None, memory=None, key=None):
            state = self.solver.init_state()
            cache = empty_branch_cache(self.cfg)
            for s in range(s_total):
                t = jnp.full((x.shape[0],), self.solver.model_times[s])
                # unrolled, so exact per-step liveness is free: collect only
                # what the next step reads, keep only what stays live
                pred, cache = self._sig_step(
                    params, x, t, label, memory, cache,
                    skip=plan.sig_at(s).skip, collect=plan.collect_at(s),
                    live=plan.live_out_at(s))
                kstep = (jax.random.fold_in(key, s)
                         if key is not None else None)
                x, state = self.solver.step(x, pred, s, state, kstep)
            return x

        return fn
