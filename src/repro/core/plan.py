"""Schedule → execution plan: segmentation + branch-cache liveness.

A static :class:`~repro.core.schedule.Schedule` touches every layer type at
every step — each step either *computes* a type (overwriting its cache slot)
or *skips* it (reading the slot).  Two structural facts follow:

* **Liveness is next-step lookahead.**  A cached branch output survives a
  step boundary iff the next step *reads* it (skips its type); a compute at
  the next step overwrites the slot before anything reads it.  A type that
  is never skipped is dead everywhere: its branches must never be
  collected, merged, or kept resident.
* **Schedules are piecewise-constant** (Δ-DiT, FORA: long runs of identical
  masks), so steps run-length encode into constant-mask segments.

The executor compiles **one program per unique signature**.  A signature is
a mask plus its *canonical* collect set ``computed(mask) ∩ ever-live`` —
canonical rather than exact-per-step so that the cache pytree structure is
a loop invariant: one ``fori_loop`` program with a dynamic
``(start, length)`` trip count then serves every segment of that mask, and
the program count equals the number of distinct masks (≤ 2^|types|)
instead of the number of mask *transitions*.  Exact per-step liveness is
still enforced at segment boundaries, where dropping dead entries is a
free Python-level pytree restructure (each :class:`SigRun` carries its
exact ``live_out``), and is available per step via
:meth:`ExecutionPlan.collect_at` / :meth:`ExecutionPlan.live_in_at` for
the unrolled monolith path and for accounting.

:func:`analyze` performs the analysis and returns an
:class:`ExecutionPlan`: the unit of provenance that
:class:`~repro.cache.artifact.CacheArtifact` serializes so a serving
process reloads a pre-analyzed plan instead of re-deriving it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Mapping, Optional, Tuple

MaskItems = Tuple[Tuple[str, bool], ...]


def schedule_fingerprint(schedule) -> str:
    """Short stable digest of a schedule's content (provenance checks) —
    memoized on the Schedule so hot-path validation stays O(1)."""
    if hasattr(schedule, "fingerprint"):
        return schedule.fingerprint()
    return hashlib.sha256(
        schedule.content_key().encode("utf-8")).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class ProgramSig:
    """Compilation signature of a segment program.

    ``mask``: sorted ``(type, skip)`` pairs — the static skip mask.
    ``collect``: sorted types whose fresh branch outputs the program writes
    into the cache — ``computed(mask) ∩ ever-live``.  Skipped types pass
    their entries through, collected types are overwritten every step, so
    the cache structure (``live_in ∪ collect``) is a loop invariant.
    """
    mask: MaskItems
    collect: Tuple[str, ...]

    @property
    def skip(self) -> Dict[str, bool]:
        return dict(self.mask)

    @property
    def live_in(self) -> Tuple[str, ...]:
        """Types whose cache entry the program *reads* (= skipped types)."""
        return tuple(sorted(t for t, sk in self.mask if sk))

    @property
    def structure(self) -> Tuple[str, ...]:
        """Types with a resident cache entry while this program runs."""
        return tuple(sorted(set(self.live_in) | set(self.collect)))


@dataclasses.dataclass(frozen=True)
class SigRun:
    """``length`` consecutive steps starting at ``start`` sharing one mask.

    ``live_out``: the *exact* live set after the run's final step — the
    types the next segment reads.  Everything else in the program's
    structure is dead at the boundary and is dropped before the next
    segment starts."""
    sig: ProgramSig
    start: int
    length: int
    live_out: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Run-length-encoded constant-mask segments for one schedule."""
    num_steps: int
    runs: Tuple[SigRun, ...]
    schedule_fingerprint: Optional[str] = None

    # -- derived -------------------------------------------------------------

    @property
    def signatures(self) -> Tuple[ProgramSig, ...]:
        """Unique signatures in order of first appearance — the compile set
        (one per distinct mask)."""
        seen: List[ProgramSig] = []
        for r in self.runs:
            if r.sig not in seen:
                seen.append(r.sig)
        return tuple(seen)

    @property
    def num_unique_signatures(self) -> int:
        return len(self.signatures)

    def run_at(self, s: int) -> SigRun:
        for r in self.runs:
            if r.start <= s < r.start + r.length:
                return r
        raise IndexError(f"step {s} outside plan of {self.num_steps} steps")

    def sig_at(self, s: int) -> ProgramSig:
        return self.run_at(s).sig

    # -- exact per-step liveness (monolith path, tests, accounting) ----------

    def live_in_at(self, s: int) -> Tuple[str, ...]:
        """Types whose cached entry step ``s`` reads (= skipped types)."""
        return self.sig_at(s).live_in

    def live_out_at(self, s: int) -> Tuple[str, ...]:
        """Exact live set after step ``s``: what step ``s+1`` reads."""
        return self.live_in_at(s + 1) if s + 1 < self.num_steps else ()

    def collect_at(self, s: int) -> Tuple[str, ...]:
        """Exact collect set of step ``s``: types computed at ``s`` whose
        output the next step reads.  (Segment programs over-collect to the
        canonical ``sig.collect`` so their carry structure is loop
        invariant; the surplus is dropped at the segment boundary.)"""
        skip = self.sig_at(s).skip
        return tuple(t for t in self.live_out_at(s) if not skip.get(t, False))

    def live_types(self) -> Tuple[str, ...]:
        """Types that are ever cached (read at some step).  A type absent
        here is *dead everywhere*: never collected, never resident."""
        out = set()
        for r in self.runs:
            out.update(r.sig.live_in)
        return tuple(sorted(out))

    def boundaries(self) -> Tuple[int, ...]:
        """Steps at which the host regains control between segments —
        every segment start plus ``num_steps`` (the end).  These are the
        join/split/merge points of continuous batching: a run advanced
        segment-by-segment sits exactly at one of them, so two runs of
        this plan are merge-compatible iff they sit on the same boundary
        (same ``run_index``)."""
        return tuple(r.start for r in self.runs) + (self.num_steps,)

    def run_label(self, i: int) -> str:
        """Human-readable tag of segment ``i`` for trace spans and logs:
        step range plus the skipped types of its mask."""
        if not 0 <= i < len(self.runs):
            raise IndexError(f"segment {i} outside plan of "
                             f"{len(self.runs)} segments")
        r = self.runs[i]
        skips = sorted(t for t, sk in r.sig.skip.items() if sk)
        return (f"seg[{i}] steps[{r.start},{r.start + r.length}) "
                f"skip={','.join(skips) if skips else '-'}")

    def summary(self) -> str:
        rows = [f"ExecutionPlan: {self.num_steps} steps, {len(self.runs)} "
                f"segments, {self.num_unique_signatures} unique signatures"]
        for r in self.runs:
            skip = [t for t, sk in r.sig.mask if sk]
            rows.append(f"  [{r.start:3d}..{r.start + r.length - 1:3d}] "
                        f"skip={skip or '∅'} "
                        f"live_out={list(r.live_out) or '∅'}")
        return "\n".join(rows)

    # -- memory accounting ---------------------------------------------------

    def peak_live_bytes(self, type_bytes: Mapping[str, int]) -> int:
        """Peak resident branch-cache bytes under the segmented path, given
        per-type cache-entry sizes (see :func:`branch_cache_type_bytes`):
        the largest per-segment structure (``live_in ∪ collect``)."""
        peak = 0
        for r in self.runs:
            for types in (r.sig.structure, r.live_out):
                peak = max(peak, sum(type_bytes.get(t, 0) for t in types))
        return peak

    # -- (de)serialization ---------------------------------------------------

    def to_jsonable(self) -> Dict:
        return {
            "num_steps": self.num_steps,
            "schedule_fingerprint": self.schedule_fingerprint,
            "runs": [{
                "start": r.start, "length": r.length,
                "mask": {t: bool(sk) for t, sk in r.sig.mask},
                "collect": list(r.sig.collect),
                "live_out": list(r.live_out),
            } for r in self.runs],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_jsonable(), sort_keys=True)

    @staticmethod
    def from_jsonable(d: Mapping) -> "ExecutionPlan":
        runs = tuple(
            SigRun(sig=ProgramSig(mask=tuple(sorted(r["mask"].items())),
                                  collect=tuple(r["collect"])),
                   start=int(r["start"]), length=int(r["length"]),
                   live_out=tuple(r["live_out"]))
            for r in d["runs"])
        return ExecutionPlan(num_steps=int(d["num_steps"]), runs=runs,
                             schedule_fingerprint=d.get("schedule_fingerprint"))

    @staticmethod
    def from_json(s: str) -> "ExecutionPlan":
        return ExecutionPlan.from_jsonable(json.loads(s))


# ---------------------------------------------------------------------------
# Analysis
# ---------------------------------------------------------------------------

def analyze(schedule) -> ExecutionPlan:
    """Segment a schedule and compute branch liveness.

    Raises if the first step reads a cache slot (nothing has filled it)."""
    s_total = schedule.num_steps
    masks = [schedule.mask_key_at(s) for s in range(s_total)]
    reads = [tuple(sorted(t for t, sk in m if sk)) for m in masks]
    if reads[0]:
        raise ValueError(
            f"schedule skips {list(reads[0])} at step 0 — the cache is empty "
            "before the first step, so step 0 must compute everything")
    ever_live = set()
    for r in reads:
        ever_live.update(r)
    spans: List[List[int]] = []           # [start, length] per mask run
    for s in range(s_total):
        if spans and masks[s] == masks[spans[-1][0]]:
            spans[-1][1] += 1
        else:
            spans.append([s, 1])
    runs = []
    for i, (start, length) in enumerate(spans):
        m = masks[start]
        collect = tuple(sorted(
            t for t, sk in m if not sk and t in ever_live))
        nxt = spans[i + 1][0] if i + 1 < len(spans) else None
        live_out = reads[nxt] if nxt is not None else ()
        runs.append(SigRun(sig=ProgramSig(mask=m, collect=collect),
                           start=start, length=length, live_out=live_out))
    return ExecutionPlan(num_steps=s_total, runs=tuple(runs),
                         schedule_fingerprint=schedule_fingerprint(schedule))


# ---------------------------------------------------------------------------
# Adaptive candidate pool
# ---------------------------------------------------------------------------

#: hard cap on ever-skipped types for pool derivation (2^n programs)
MAX_LATTICE_TYPES = 8


def mask_lattice(schedule) -> Tuple[ProgramSig, ...]:
    """Candidate signature pool for input-adaptive runtime dispatch: the
    full mask lattice over the schedule's *ever-skipped* type set.

    A runtime policy (``repro.cache.AdaptivePolicy``) decides per step which
    layer types to reuse, so ahead of time we only know the *menu* of masks
    it may pick: any subset of the types the offline schedule ever skips
    (types the offline analysis deems cache-eligible).  This returns one
    :class:`ProgramSig` per subset — ``2^|ever-skipped|`` signatures,
    typically 4 for {attn, ffn} — with the canonical collect set
    ``computed ∩ ever-skipped``.  That choice makes every pool signature's
    cache structure the *same* set (the ever-skipped types), so the branch
    cache pytree is invariant across the whole adaptive run and per-step
    dispatch among precompiled programs needs no restructuring.

    The pool is ordered by skip-set size (all-compute first) and contains
    every mask of the static schedule, so a τ=0 adaptive run dispatches the
    exact static masks.  The executor compiles at most ``len(pool)``
    programs, never one per step.
    """
    masks = [schedule.mask_key_at(s) for s in range(schedule.num_steps)]
    types = sorted(t for t, _ in masks[0])
    ever = sorted({t for m in masks for t, sk in m if sk})
    if len(ever) > MAX_LATTICE_TYPES:
        raise ValueError(
            f"mask lattice over {len(ever)} skippable types would need "
            f"2^{len(ever)} programs; restrict the base schedule (e.g. a "
            "per_type composite with NoCache for some types)")
    subsets: List[Tuple[str, ...]] = [()]
    for t in ever:
        subsets += [sub + (t,) for sub in subsets]
    subsets.sort(key=lambda sub: (len(sub), sub))
    pool = []
    for sub in subsets:
        skipset = set(sub)
        mask = tuple(sorted((t, t in skipset) for t in types))
        collect = tuple(sorted(t for t in ever if t not in skipset))
        pool.append(ProgramSig(mask=mask, collect=collect))
    return tuple(pool)


def pool_index(pool) -> Dict[frozenset, ProgramSig]:
    """Runtime dispatch table: frozenset of skipped types → signature."""
    return {frozenset(sig.live_in): sig for sig in pool}


def mask_signature(types, bits) -> Tuple[str, ...]:
    """Canonical hashable mask signature from per-type skip bits (bit
    order follows ``types``) — the key continuous serving regroups rows
    by at chunk boundaries: rows whose desired signatures agree can share
    a batch without forcing each other's computes."""
    return tuple(t for t, hit in zip(types, bits) if hit)


@dataclasses.dataclass(frozen=True)
class SwitchTable:
    """On-device dispatch table over a candidate pool: ``branches[code]``
    is the signature whose skip set is ``{types[i] : bit i of code}``, so
    a fused sampling program can turn per-type skip bits into a
    ``lax.switch`` branch index with one dot product against ``2^i`` —
    no host round-trip.  Hashable (it keys the executor's compiled-variant
    table: one fused program per table)."""
    types: Tuple[str, ...]                    # bit order (sorted)
    branches: Tuple[ProgramSig, ...]          # len == 2^len(types)

    def code_of(self, skipset) -> int:
        """Host-side branch index of a skip set (tests, accounting)."""
        skipset = set(skipset)
        unknown = skipset - set(self.types)
        if unknown:
            raise KeyError(f"skip set contains types {sorted(unknown)} "
                           f"outside the pool {list(self.types)}")
        return sum(1 << i for i, t in enumerate(self.types) if t in skipset)


def switch_branch_table(pool) -> SwitchTable:
    """Arrange a candidate pool for ``lax.switch`` dispatch.

    Requires the *full* mask lattice (every subset of the pool's type set
    present — :func:`mask_lattice` constructs exactly that): the fused
    program computes the branch index arithmetically from the per-type
    skip bits, so every bit pattern must name a signature."""
    idx = pool_index(pool)
    union = frozenset().union(*idx) if idx else frozenset()
    types = tuple(sorted(union))
    branches = []
    for code in range(1 << len(types)):
        skipset = frozenset(t for i, t in enumerate(types)
                            if code >> i & 1)
        sig = idx.get(skipset)
        if sig is None:
            raise ValueError(
                f"candidate pool is not a full mask lattice over "
                f"{list(types)}: skip set {sorted(skipset)} has no "
                "signature — derive the pool via mask_lattice()")
        branches.append(sig)
    return SwitchTable(types=types, branches=tuple(branches))


# ---------------------------------------------------------------------------
# Cache-size accounting
# ---------------------------------------------------------------------------

def branch_cache_type_bytes(cfg, batch: int, *, dtype_bytes: int = 4,
                            cfg_doubled: bool = False) -> Dict[str, int]:
    """Bytes of one resident cache entry per layer *type*: every layer of the
    type holds one pre-residual output of shape (B, N, d_model)."""
    from repro.core import diffusion  # late import: diffusion imports models
    n_tok, _, _ = diffusion.token_shape(cfg)
    b = 2 * batch if cfg_doubled else batch
    per_layer = b * n_tok * cfg.d_model * dtype_bytes
    out: Dict[str, int] = {}
    for st in cfg.stages:
        for blk in st.unit:
            for t in blk.branch_types():
                out[t] = out.get(t, 0) + st.repeat * per_layer
    return out
