"""Diffusion wrapper: turns any repro backbone into a DiT denoiser.

Adds patchify/unpatchify, sinusoidal timestep embedding → MLP, optional
class-label embedding (with a CFG null class), and adaLN-zero conditioning
(the backbone's blocks carry ``adaln=True``).  Works for image latents
(H, W, C), video latents (T, H, W, C — spatial patchify, factorized
attention) and audio latents (L, C).

Prediction types: "eps" (DDPM/DDIM/DPM++) and "v_rf" (rectified flow).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L, transformer as T

TIME_EMB_DIM = 256


# ---------------------------------------------------------------------------
# Patchify
# ---------------------------------------------------------------------------

def token_shape(cfg: ModelConfig):
    """Returns (num_tokens, token_dim, video_shape or None)."""
    ls = cfg.latent_shape
    p = cfg.patch
    if len(ls) == 3:    # (H, W, C) image
        h, w, c = ls
        return (h // p) * (w // p), p * p * c, None
    if len(ls) == 4:    # (T, H, W, C) video — spatial patchify only
        t, h, w, c = ls
        s = (h // p) * (w // p)
        return t * s, p * p * c, (t, s)
    ll, c = ls          # (L, C) audio
    assert p == 1
    return ll, c, None


def patchify(cfg: ModelConfig, x):
    """x: (B, *latent_shape) → (B, N, token_dim)."""
    p = cfg.patch
    ls = cfg.latent_shape
    b = x.shape[0]
    if len(ls) == 3:
        h, w, c = ls
        x = x.reshape(b, h // p, p, w // p, p, c)
        return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, (h // p) * (w // p), p * p * c)
    if len(ls) == 4:
        t, h, w, c = ls
        x = x.reshape(b, t, h // p, p, w // p, p, c)
        return x.transpose(0, 1, 2, 4, 3, 5, 6).reshape(
            b, t * (h // p) * (w // p), p * p * c)
    return x


def unpatchify(cfg: ModelConfig, tok):
    p = cfg.patch
    ls = cfg.latent_shape
    b = tok.shape[0]
    if len(ls) == 3:
        h, w, c = ls
        x = tok.reshape(b, h // p, w // p, p, p, c)
        return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, h, w, c)
    if len(ls) == 4:
        t, h, w, c = ls
        x = tok.reshape(b, t, h // p, w // p, p, p, c)
        return x.transpose(0, 1, 2, 4, 3, 5, 6).reshape(b, t, h, w, c)
    return tok


# ---------------------------------------------------------------------------
# Wrapper params
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    assert cfg.task == "diffusion"
    n_tok, tok_dim, _ = token_shape(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p = {
        "backbone": T.init_params(ks[0], cfg, dtype, adaln_dim=d),
        "patch_in": {"w": L.dense_init(ks[1], tok_dim, d, dtype),
                     "b": L.zeros((d,), dtype)},
        "t_mlp": {"w1": L.dense_init(ks[2], TIME_EMB_DIM, d, dtype),
                  "b1": L.zeros((d,), dtype),
                  "w2": L.dense_init(ks[3], d, d, dtype),
                  "b2": L.zeros((d,), dtype)},
        # adaLN-zero final layer: cond → (shift, scale); zero-init out proj
        "final_mod": {"w": L.zeros((d, 2 * d), dtype),
                      "b": L.zeros((2 * d,), dtype)},
        "out": {"w": L.zeros((d, tok_dim), dtype),
                "b": L.zeros((tok_dim,), dtype)},
    }
    if cfg.num_classes:
        # +1 slot = CFG null label
        p["label_embed"] = L.embed_init(ks[4], cfg.num_classes + 1, d, dtype)
    return p


def _cond_vector(cfg: ModelConfig, params, t, label=None):
    """t: (B,) diffusion time in [0, 1000) or [0,1]; label: (B,) int."""
    te = L.sinusoidal_embedding(t.astype(jnp.float32), TIME_EMB_DIM)
    te = jax.nn.silu(te @ params["t_mlp"]["w1"] + params["t_mlp"]["b1"])
    te = te @ params["t_mlp"]["w2"] + params["t_mlp"]["b2"]
    if label is not None and "label_embed" in params:
        te = te + jnp.take(params["label_embed"], label, axis=0)
    return te


def apply(cfg: ModelConfig, params, x, t, *, label=None, memory=None,
          skip=None, branch_caches=None, collect_branches=False,
          use_flash=False):
    """Denoiser: x (B, *latent_shape), t (B,) → prediction (B, *latent_shape).

    Returns (pred, aux) with aux["branch"] holding per-layer pre-residual
    branch outputs (the SmoothCache payload) when requested.
    ``collect_branches`` may be a bool or a collection of layer types — the
    executor's liveness analysis passes the exact set of types whose fresh
    outputs a later step will read, so dead branches are never stacked."""
    _, _, video_shape = token_shape(cfg)
    tok = patchify(cfg, x)
    h = tok @ params["patch_in"]["w"] + params["patch_in"]["b"]
    # fixed sin-cos positional embedding over flattened tokens (DiT-style)
    pos = jnp.arange(h.shape[1])
    h = h + L.sinusoidal_embedding(pos, cfg.d_model)[None].astype(h.dtype)
    cond = _cond_vector(cfg, params, t, label)
    out, aux = T.forward(
        cfg, params["backbone"], embeds=h, memory=memory, cond=cond,
        skip=skip, branch_caches=branch_caches,
        collect_branches=collect_branches,
        use_flash=use_flash, video_shape=video_shape)
    mod = jax.nn.silu(cond) @ params["final_mod"]["w"] + params["final_mod"]["b"]
    shift, scale = jnp.split(mod[:, None, :], 2, axis=-1)
    out = out * (1.0 + scale) + shift
    out = out @ params["out"]["w"] + params["out"]["b"]
    return unpatchify(cfg, out), aux


# ---------------------------------------------------------------------------
# VP forward process + training losses
# ---------------------------------------------------------------------------

def vp_schedule(num_train_steps: int = 1000, beta_start: float = 1e-4,
                beta_end: float = 2e-2):
    betas = jnp.linspace(beta_start, beta_end, num_train_steps, dtype=jnp.float32)
    alphas = 1.0 - betas
    alpha_bar = jnp.cumprod(alphas)
    return {"betas": betas, "alphas": alphas, "alpha_bar": alpha_bar}


def q_sample(sched, x0, t, noise):
    """VP forward: x_t = sqrt(ᾱ_t) x₀ + sqrt(1-ᾱ_t) ε.  t: (B,) int."""
    ab = sched["alpha_bar"][t]
    shape = (-1,) + (1,) * (x0.ndim - 1)
    return (jnp.sqrt(ab).reshape(shape) * x0
            + jnp.sqrt(1.0 - ab).reshape(shape) * noise)


def eps_loss(cfg, params, key, x0, *, sched, label=None, memory=None):
    """DDPM ε-prediction loss."""
    kt, kn = jax.random.split(key)
    b = x0.shape[0]
    t = jax.random.randint(kt, (b,), 0, sched["betas"].shape[0])
    noise = jax.random.normal(kn, x0.shape, x0.dtype)
    xt = q_sample(sched, x0, t, noise)
    pred, _ = apply(cfg, params, xt, t, label=label, memory=memory)
    return jnp.mean(jnp.square(pred - noise))


def rf_loss(cfg, params, key, x0, *, label=None, memory=None):
    """Rectified-flow velocity loss: x_t = (1-t)x₀ + t·ε, v* = ε − x₀."""
    kt, kn = jax.random.split(key)
    b = x0.shape[0]
    t = jax.random.uniform(kt, (b,))
    noise = jax.random.normal(kn, x0.shape, x0.dtype)
    shape = (-1,) + (1,) * (x0.ndim - 1)
    xt = (1.0 - t).reshape(shape) * x0 + t.reshape(shape) * noise
    pred, _ = apply(cfg, params, xt, t * 1000.0, label=label, memory=memory)
    return jnp.mean(jnp.square(pred - (noise - x0)))
