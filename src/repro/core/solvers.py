"""Diffusion samplers used by the paper: DDIM (DiT-XL), DPM-Solver++(3M) SDE
(Stable Audio Open) and Rectified-Flow Euler (OpenSora).

All solvers are expressed as a pair:

    timesteps(num_steps)         → per-step model times t_s (static)
    step(x, model_out, s, state) → (x_next, state)

so the SmoothCache executor owns the model-call loop and can substitute
cached layer outputs at any step.  The model interface is ε-prediction for
DDIM/DPM++ (VP schedule) and velocity for rectified flow.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import diffusion


@dataclasses.dataclass
class Solver:
    name: str
    num_steps: int
    model_times: jnp.ndarray                 # (S,) times fed to the model
    init_state: Callable[[], dict]
    step: Callable                           # (x, model_out, s, state, key)
    stochastic: bool = False
    # step() accepts a *traced* step index and a structure-stable state, so
    # the executor may run it inside lax.fori_loop / lax.scan segments
    scannable: bool = True


# ---------------------------------------------------------------------------
# DDIM (η = 0) on the VP schedule — the paper's DiT-XL protocol
# ---------------------------------------------------------------------------

def ddim(num_steps: int, sched=None, num_train_steps: int = 1000) -> Solver:
    sched = sched or diffusion.vp_schedule(num_train_steps)
    ts = jnp.linspace(num_train_steps - 1, 0, num_steps).round().astype(jnp.int32)
    ab = sched["alpha_bar"][ts]                                  # (S,)
    ab_next = jnp.concatenate([sched["alpha_bar"][ts[1:]], jnp.ones((1,))])

    def step(x, eps, s, state, key=None):
        a, an = ab[s], ab_next[s]
        shape = (1,) * x.ndim
        x0 = (x - jnp.sqrt(1 - a) * eps) / jnp.sqrt(a)
        x = jnp.sqrt(an) * x0 + jnp.sqrt(1 - an) * eps
        return x, state

    return Solver("ddim", num_steps, ts.astype(jnp.float32),
                  lambda: {}, step)


# ---------------------------------------------------------------------------
# DPM-Solver++(3M) SDE — the paper's Stable Audio Open protocol
# (k-diffusion formulation on σ = sqrt(1-ᾱ)/sqrt(ᾱ); model stays ε-pred,
#  converted to x̂₀ internally)
# ---------------------------------------------------------------------------

def dpmpp_3m_sde(num_steps: int, sched=None, num_train_steps: int = 1000,
                 eta: float = 1.0) -> Solver:
    sched = sched or diffusion.vp_schedule(num_train_steps)
    ts = jnp.linspace(num_train_steps - 1, 1, num_steps).round().astype(jnp.int32)
    ab = sched["alpha_bar"][ts]
    sigmas = jnp.sqrt((1 - ab) / ab)                             # VE view
    sigmas = jnp.concatenate([sigmas, jnp.zeros((1,))])

    def init_state():
        return {"d1": None, "d2": None, "h1": None, "h2": None}

    def step(x_vp, eps, s, state, key=None):
        # VP → VE coordinates (s is a static python step index)
        a = ab[s]
        x = x_vp / jnp.sqrt(a)
        sig, sig_next = sigmas[s], sigmas[s + 1]
        denoised = x - sig * eps           # x̂₀ in VE coords
        if s == num_steps - 1:             # final step: σ→0, x = x̂₀
            x_new = denoised
        else:
            t, snext = -jnp.log(sig), -jnp.log(sig_next)
            h = snext - t
            h_eta = h * (eta + 1.0)
            x_new = jnp.exp(-h_eta) * x + (-jnp.expm1(-h_eta)) * denoised
            if state["d2"] is not None:
                r0, r1 = state["h1"] / h, state["h2"] / h
                d1_0 = (denoised - state["d1"]) / r0
                d1_1 = (state["d1"] - state["d2"]) / r1
                d1 = d1_0 + (d1_0 - d1_1) * r0 / (r0 + r1)
                d2 = (d1_0 - d1_1) / (r0 + r1)
                phi2 = jnp.expm1(-h_eta) / h_eta + 1.0
                phi3 = phi2 / h_eta - 0.5
                x_new = x_new + phi2 * d1 - phi3 * d2
            elif state["d1"] is not None:
                r = state["h1"] / h
                d = (denoised - state["d1"]) / r
                phi2 = jnp.expm1(-h_eta) / h_eta + 1.0
                x_new = x_new + phi2 * d
            if eta > 0 and key is not None:
                noise = jax.random.normal(key, x.shape, x.dtype)
                x_new = x_new + noise * sig_next * jnp.sqrt(
                    -jnp.expm1(-2.0 * h * eta))
            state = {"d1": denoised, "d2": state["d1"],
                     "h1": h, "h2": state["h1"]}
        # back to VP coordinates at the *next* sigma level
        ab_next = 1.0 / (1.0 + sigmas[s + 1] ** 2)
        return x_new * jnp.sqrt(ab_next), state

    # not scannable: step() branches in Python on the step index (final-step
    # σ→0 shortcut) and the multistep state changes *structure* (None → array)
    # over the first three steps
    return Solver("dpmpp_3m_sde", num_steps, ts.astype(jnp.float32),
                  init_state, step, stochastic=True, scannable=False)


# ---------------------------------------------------------------------------
# Rectified-Flow Euler — the paper's OpenSora protocol
# (model predicts v = ε − x₀; integrate x from t=1 (noise) to t=0)
# ---------------------------------------------------------------------------

def rectified_flow(num_steps: int, num_train_steps: int = 1000) -> Solver:
    # model times: t ∈ (0, 1] scaled by 1000 as during training
    tgrid = jnp.linspace(1.0, 0.0, num_steps + 1)

    def step(x, v, s, state, key=None):
        dt = tgrid[s + 1] - tgrid[s]           # negative
        return x + dt * v, state

    return Solver("rectified_flow", num_steps, tgrid[:-1] * 1000.0,
                  lambda: {}, step)


SOLVERS = {
    "ddim": ddim,
    "dpmpp_3m_sde": dpmpp_3m_sde,
    "rectified_flow": rectified_flow,
}
