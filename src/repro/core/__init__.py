from repro.core import calibration, diffusion, executor, schedule, solvers  # noqa: F401
