"""SmoothCache calibration: run uncached sampling trajectories, record every
layer's pre-residual branch output at every step, and build the per-type L1
relative error curves of paper Fig. 2 / Eq. 4.

The error at step s for lag k is

    err[t][s, k] = mean_{j ∈ layers of type t}
                   ||L̃_{j}(s) − L̃_{j}(s−k)||₁ / ||L̃_{j}(s)||₁

averaged over calibration samples; per-sample curves are also returned so
the Fig. 2 confidence intervals can be reproduced.

Under classifier-free guidance the executor doubles the batch to
``[cond; uncond]``; calibration keeps only the **conditioned half**, so
per-sample curves have leading dim ``calib_batch`` (not ``2*calib_batch``)
and the mean curves never mix guided and unguided error statistics.

For input-adaptive policies (:class:`repro.cache.AdaptivePolicy`) the same
pass additionally records a cheap per-step **proxy signal** — the relative
L1 change of the model input (the latent) between consecutive steps, the
exact quantity the runtime rule can compute before each model call — and
:func:`fit_proxy_map` fits a per-type linear proxy→error mapping that the
runtime accumulates against its threshold τ.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def branch_outputs_by_type(cfg: ModelConfig, branch_tree) -> Dict[str, List[np.ndarray]]:
    """Flatten the per-stage scan-stacked branch outputs into
    {type: [per-layer arrays (B, N, d)] in depth order}."""
    out: Dict[str, List[np.ndarray]] = {}
    for si, st in enumerate(cfg.stages):
        stage_branches = branch_tree[si]          # tuple per block in unit
        for bi, b in enumerate(st.unit):
            bo = stage_branches[bi]
            names = b.branch_names()
            types = b.branch_types()
            for name, t in zip(names, types):
                if bo is None or name not in bo:
                    continue
                arr = np.asarray(bo[name])        # (repeat, B, N, d)
                for r in range(arr.shape[0]):
                    out.setdefault(t, []).append(arr[r])
    return out


def l1_rel_error(a: np.ndarray, b: np.ndarray, axis=None) -> np.ndarray:
    """||a − b||₁ / ||a||₁ (per-sample when axis keeps the batch dim)."""
    num = np.sum(np.abs(a - b), axis=axis)
    den = np.sum(np.abs(a), axis=axis) + 1e-12
    return num / den


def error_curves_from_trajectory(cfg: ModelConfig,
                                 per_step: List[Dict[str, List[np.ndarray]]],
                                 k_max: int = 3):
    """per_step[s] = branch_outputs_by_type at sampling step s.

    Returns (mean_curves {t: (S, K+1)}, per_sample {t: (B, S, K+1)}).
    Entries with k > s are NaN; k=0 column is 0.
    """
    s_total = len(per_step)
    types = sorted(per_step[0].keys())
    bsz = per_step[0][types[0]][0].shape[0]
    mean_curves = {t: np.full((s_total, k_max + 1), np.nan) for t in types}
    per_sample = {t: np.full((bsz, s_total, k_max + 1), np.nan) for t in types}
    for t in types:
        for s in range(s_total):
            per_sample[t][:, s, 0] = 0.0
            mean_curves[t][s, 0] = 0.0
            for k in range(1, min(k_max, s) + 1):
                errs = []
                for lj, (cur, prev) in enumerate(zip(per_step[s][t],
                                                     per_step[s - k][t])):
                    # per-sample L1 over all non-batch axes
                    ax = tuple(range(1, cur.ndim))
                    errs.append(l1_rel_error(cur, prev, axis=ax))
                e = np.mean(np.stack(errs, 0), axis=0)   # layer-mean, (B,)
                per_sample[t][:, s, k] = e
                mean_curves[t][s, k] = float(np.mean(e))
    return mean_curves, per_sample


# ---------------------------------------------------------------------------
# Proxy signal (input-adaptive policies)
# ---------------------------------------------------------------------------

def rel_l1_change(cur, prev):
    """||cur − prev||₁ / ||prev||₁ over the whole tensor — THE proxy
    formula, written backend-agnostically (``__abs__``/``.sum()``) so the
    calibration pass (numpy, float64) and the executor's jitted runtime
    proxy (jax, device dtype) provably compute the same signal from one
    definition."""
    return abs(cur - prev).sum() / (abs(prev).sum() + 1e-12)


def rel_l1_change_rows(cur, prev):
    """Per-sample :func:`rel_l1_change`: reduce over every axis but the
    leading batch axis, returning one proxy signal per row.  Same
    arithmetic as the whole-tensor form restricted to each row, so a
    batch-1 run and row i of a batch-B run see the same signal — the
    per-sample decision analogue of the executor's per-row bitwise
    latent stability."""
    axes = tuple(range(1, cur.ndim))
    return (abs(cur - prev).sum(axis=axes)
            / (abs(prev).sum(axis=axes) + 1e-12))


def runtime_rule(proxy, acc, lag, a, b, tau, k_max, force_compute=False):
    """One evaluation of the adaptive reuse rule, vectorized over layer
    types: estimate the per-type lag-1 error from the proxy signal
    (``max(a·proxy + b, 0)`` — clamped, so an adversarial fit can never
    shrink the accumulator while skipping), skip a type while the error
    accumulated since its last compute stays under ``tau`` and the cache
    age stays ≤ ``k_max``, and return the updated accumulator/lag state.

    THE decision arithmetic: the executor's fused sampling program inlines
    it into its ``fori_loop`` body and the host-dispatch path jits it
    standalone, so fused and host decision sequences agree bit-for-bit.
    ``acc``/``a``/``b`` are float32, ``lag`` int32; ``force_compute``
    (step 0, empty cache) overrides every skip."""
    delta = jnp.maximum(a * proxy + b, 0.0)
    skip = ((lag + 1 <= k_max) & (acc + delta < tau)
            & jnp.logical_not(force_compute))
    acc = jnp.where(skip, acc + delta, 0.0)
    lag = jnp.where(skip, lag + 1, 0)
    return skip, acc, lag


def batch_rule(proxy_rows, acc, lag, a, b, tau, k_max, force_compute=False):
    """Per-sample adaptive rule over a batch: each row evaluates
    :func:`runtime_rule` arithmetic against its OWN ``(B, T)``
    accumulator/lag state from its own proxy signal, yielding the
    per-row *desired* skip bits ``want (B, T)``; the batch *realizes*
    their AND (``realized (T,)`` — any row needing a type's compute
    forces the whole batch to compute it, since one model call refreshes
    that type's cache for every row).

    acc/lag update against the REALIZED bits: a forced compute refreshes
    the cache for all rows, so every row's accumulator for that type
    resets — each row's state tracks the error actually accrued in its
    cache entries, not a counterfactual solo trajectory.  A batch of one
    therefore realizes exactly its solo trajectory, which is what makes
    split/merge and boundary regroup deterministic per row."""
    delta = jnp.maximum(a * proxy_rows[:, None] + b[None, :], 0.0)  # (B, T)
    want = ((lag + 1 <= k_max) & (acc + delta < tau)
            & jnp.logical_not(force_compute))
    realized = jnp.all(want, axis=0)                                # (T,)
    acc = jnp.where(realized[None, :], acc + delta, 0.0)
    lag = jnp.where(realized[None, :], lag + 1, 0)
    return want, realized, acc, lag


def proxy_signal(cur, prev) -> float:
    """Relative L1 change of the model input between consecutive steps —
    one scalar per step over the whole batch tensor.  This is the runtime
    decision signal: it needs only the latents, so it is computable
    *before* the model call it gates."""
    return float(rel_l1_change(np.asarray(cur, np.float64),
                               np.asarray(prev, np.float64)))


def proxies_from_inputs(inputs: List[np.ndarray]) -> np.ndarray:
    """Per-step proxy signals from the model-input trajectory.
    ``proxies[0]`` is NaN (no previous input); ``proxies[s]`` compares the
    inputs of steps s and s−1."""
    out = np.full(len(inputs), np.nan)
    for s in range(1, len(inputs)):
        out[s] = proxy_signal(inputs[s], inputs[s - 1])
    return out


@dataclasses.dataclass(frozen=True)
class ProxyMap:
    """Fitted per-type linear map from the proxy signal to the one-step
    (lag-1) relative output error: ``est_t(p) = max(a_t·p + b_t, 0)``.

    The clamp at zero is load-bearing: an adversarial fit (negative slope
    or intercept) would otherwise yield negative per-type estimates, so the
    accumulator could *decrease* while a type keeps skipping and postpone
    its recompute indefinitely.  Both the scalar :meth:`est` and the device
    rule (:func:`runtime_rule` over :meth:`stacked` coefficients) clamp.

    The runtime rule accumulates ``est_t(proxy_s)`` over consecutive
    reuse steps and recomputes type ``t`` once the sum would cross τ —
    TeaCache-style, but with the mapping *fitted during calibration* and
    shipped in the :class:`~repro.cache.artifact.CacheArtifact` so serving
    never recalibrates."""
    coeffs: Dict[str, Tuple[float, float]]   # type → (a, b)
    mean_proxy: float = float("nan")         # calibration-mean proxy (diag)

    def est(self, t: str, proxy: float) -> float:
        a, b = self.coeffs[t]
        return max(a * float(proxy) + b, 0.0)

    def stacked(self, types) -> Tuple[np.ndarray, np.ndarray]:
        """Device representation: per-type ``(a, b)`` coefficients stacked
        into two float32 arrays in the given type order — what the fused
        sampling program (and the host decide step, for parity) evaluates
        as one vectorized ``max(a·p + b, 0)``."""
        missing = [t for t in types if t not in self.coeffs]
        if missing:
            raise KeyError(f"proxy_map lacks coefficients for {missing}; "
                           f"have {self.types()}")
        a = np.asarray([self.coeffs[t][0] for t in types], np.float32)
        b = np.asarray([self.coeffs[t][1] for t in types], np.float32)
        return a, b

    def types(self):
        return sorted(self.coeffs)

    def to_jsonable(self) -> Dict:
        return {"coeffs": {t: [float(a), float(b)]
                           for t, (a, b) in sorted(self.coeffs.items())},
                "mean_proxy": None if np.isnan(self.mean_proxy)
                else float(self.mean_proxy)}

    @staticmethod
    def from_jsonable(d: Mapping) -> "ProxyMap":
        mp = d.get("mean_proxy")
        return ProxyMap(
            coeffs={t: (float(a), float(b))
                    for t, (a, b) in d["coeffs"].items()},
            mean_proxy=float("nan") if mp is None else float(mp))


def fit_proxy_map(curves: Mapping[str, np.ndarray],
                  proxies: np.ndarray) -> ProxyMap:
    """Least-squares fit of the lag-1 error column against the proxy
    signal, per layer type.  Degenerate data (fewer than two finite points,
    or a constant proxy) falls back to the constant map ``b = mean(err)``,
    which still yields a sensible accumulate-and-threshold rule."""
    coeffs = {}
    for t, err in curves.items():
        xs = np.asarray(proxies, np.float64)
        ys = np.asarray(err[:, 1], np.float64)       # lag-1 column
        ok = np.isfinite(xs) & np.isfinite(ys)
        xs, ys = xs[ok], ys[ok]
        if xs.size >= 2 and np.ptp(xs) > 1e-12:
            a, b = np.polyfit(xs, ys, 1)
        else:
            a, b = 0.0, float(np.mean(ys)) if ys.size else 0.0
        coeffs[t] = (float(a), float(b))
    finite = np.asarray(proxies)[np.isfinite(proxies)]
    return ProxyMap(coeffs=coeffs,
                    mean_proxy=float(np.mean(finite)) if finite.size
                    else float("nan"))


# ---------------------------------------------------------------------------
# Calibration passes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationRecord:
    """Everything one uncached calibration pass produces."""
    curves: Dict[str, np.ndarray]        # {type: (S, K+1)} mean curves
    per_sample: Dict[str, np.ndarray]    # {type: (calib_batch, S, K+1)}
    proxies: np.ndarray                  # (S,) per-step proxy signal
    proxy_map: ProxyMap                  # fitted proxy→lag-1-error map
    x0: np.ndarray                       # final denoised latents
    cfg_halved: bool                     # True → cond half of a CFG batch


def calibrate_record(executor, params, key, batch: int, *, cond_args=None,
                     k_max: int = 3) -> CalibrationRecord:
    """Run one uncached sampling pass with ``batch`` calibration samples
    (paper uses 10), recording branch outputs *and* the per-step proxy
    signal, and fit the proxy→error map.

    Under CFG the executor doubles the batch to ``[cond; uncond]``; only
    the conditioned half enters the curves (``per_sample`` leading dim is
    exactly ``batch``)."""
    cond_args = cond_args or {}
    cfg_halved = executor.cfg_scale is not None
    per_step: List[Dict[str, List[np.ndarray]]] = []

    def hook(s, branch_tree):
        by_type = branch_outputs_by_type(executor.cfg, branch_tree)
        if cfg_halved:
            # keep the conditioned half of the [cond; uncond] doubled batch
            by_type = {t: [a[:batch] for a in arrs]
                       for t, arrs in by_type.items()}
        per_step.append(by_type)

    x_init, _ = executor.initial_latent(key, batch)
    x0, traj = executor.sample(params, key, batch, schedule=None,
                               collect_hook=hook, return_trajectory=True,
                               **cond_args)
    # model input at step s: the initial noise for s=0, else the latent
    # produced by step s−1
    inputs = [np.asarray(x_init)] + [np.asarray(x) for x in traj[:-1]]
    proxies = proxies_from_inputs(inputs)
    curves, per_sample = error_curves_from_trajectory(
        executor.cfg, per_step, k_max=k_max)
    return CalibrationRecord(
        curves=curves, per_sample=per_sample, proxies=proxies,
        proxy_map=fit_proxy_map(curves, proxies), x0=np.asarray(x0),
        cfg_halved=cfg_halved)


def calibrate(executor, params, key, batch: int, *, cond_args=None,
              k_max: int = 3):
    """Back-compat wrapper over :func:`calibrate_record`:
    returns (mean_curves, per_sample, trajectory x₀)."""
    rec = calibrate_record(executor, params, key, batch,
                           cond_args=cond_args, k_max=k_max)
    return rec.curves, rec.per_sample, rec.x0
