"""SmoothCache calibration: run uncached sampling trajectories, record every
layer's pre-residual branch output at every step, and build the per-type L1
relative error curves of paper Fig. 2 / Eq. 4.

The error at step s for lag k is

    err[t][s, k] = mean_{j ∈ layers of type t}
                   ||L̃_{j}(s) − L̃_{j}(s−k)||₁ / ||L̃_{j}(s)||₁

averaged over calibration samples; per-sample curves are also returned so
the Fig. 2 confidence intervals can be reproduced.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def branch_outputs_by_type(cfg: ModelConfig, branch_tree) -> Dict[str, List[np.ndarray]]:
    """Flatten the per-stage scan-stacked branch outputs into
    {type: [per-layer arrays (B, N, d)] in depth order}."""
    out: Dict[str, List[np.ndarray]] = {}
    for si, st in enumerate(cfg.stages):
        stage_branches = branch_tree[si]          # tuple per block in unit
        for bi, b in enumerate(st.unit):
            bo = stage_branches[bi]
            names = b.branch_names()
            types = b.branch_types()
            for name, t in zip(names, types):
                if bo is None or name not in bo:
                    continue
                arr = np.asarray(bo[name])        # (repeat, B, N, d)
                for r in range(arr.shape[0]):
                    out.setdefault(t, []).append(arr[r])
    return out


def l1_rel_error(a: np.ndarray, b: np.ndarray, axis=None) -> np.ndarray:
    """||a − b||₁ / ||a||₁ (per-sample when axis keeps the batch dim)."""
    num = np.sum(np.abs(a - b), axis=axis)
    den = np.sum(np.abs(a), axis=axis) + 1e-12
    return num / den


def error_curves_from_trajectory(cfg: ModelConfig,
                                 per_step: List[Dict[str, List[np.ndarray]]],
                                 k_max: int = 3):
    """per_step[s] = branch_outputs_by_type at sampling step s.

    Returns (mean_curves {t: (S, K+1)}, per_sample {t: (B, S, K+1)}).
    Entries with k > s are NaN; k=0 column is 0.
    """
    s_total = len(per_step)
    types = sorted(per_step[0].keys())
    bsz = per_step[0][types[0]][0].shape[0]
    mean_curves = {t: np.full((s_total, k_max + 1), np.nan) for t in types}
    per_sample = {t: np.full((bsz, s_total, k_max + 1), np.nan) for t in types}
    for t in types:
        for s in range(s_total):
            per_sample[t][:, s, 0] = 0.0
            mean_curves[t][s, 0] = 0.0
            for k in range(1, min(k_max, s) + 1):
                errs = []
                for lj, (cur, prev) in enumerate(zip(per_step[s][t],
                                                     per_step[s - k][t])):
                    # per-sample L1 over all non-batch axes
                    ax = tuple(range(1, cur.ndim))
                    errs.append(l1_rel_error(cur, prev, axis=ax))
                e = np.mean(np.stack(errs, 0), axis=0)   # layer-mean, (B,)
                per_sample[t][:, s, k] = e
                mean_curves[t][s, k] = float(np.mean(e))
    return mean_curves, per_sample


def calibrate(executor, params, key, batch: int, *, cond_args=None,
              k_max: int = 3):
    """Run one uncached sampling pass with ``batch`` calibration samples
    (paper uses 10) and return (mean_curves, per_sample, trajectory x₀)."""
    cond_args = cond_args or {}
    per_step: List[Dict[str, List[np.ndarray]]] = []

    def hook(s, branch_tree):
        per_step.append(branch_outputs_by_type(executor.cfg, branch_tree))

    x0 = executor.sample(params, key, batch, schedule=None,
                         collect_hook=hook, **cond_args)
    curves, per_sample = error_curves_from_trajectory(
        executor.cfg, per_step, k_max=k_max)
    return curves, per_sample, x0
