"""Span/event tracer → Chrome trace-event JSON (Perfetto-loadable).

The serving stack is a scheduler: the interesting questions ("why was
this batch slow?", "what did the engine do during the overload ramp?")
are about *intervals* and their nesting, not aggregates.  The tracer
records them as Chrome trace events — duration spans (``B``/``E``) on
one track (``tid``) per in-flight batch, instant events (``i``) for
point occurrences (rung moves, watchdog fires, retries, sheds) — so a
recorded serve session drops straight into Perfetto / ``chrome://tracing``.

Design constraints, in order:

* **~zero cost when disabled.**  Engine code holds a tracer
  unconditionally; the disabled case is :data:`NULL_TRACER`, whose
  methods are empty — no conditionals at call sites, no event storage.
* **Clock-agnostic.**  Anything with a ``now() -> float`` (seconds)
  works: the serving stack's ``WallClock``/``VirtualClock``, or the
  default ``time.monotonic`` wrapper.  Virtual-clock traces are exactly
  reproducible, which is what the overhead benchmark diffs.
* **Cheap while enabled.**  Recording is one tuple append; all JSON
  shaping happens at export time.

Matched-pair discipline is enforced at record time (``end`` without an
open span raises) and re-checked structurally by
:func:`validate_chrome_trace`, which the benchmark runs on the exported
JSON — monotonic timestamps per track, every ``B`` closed by its ``E``.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple


class _MonotonicClock:
    """Fallback clock when the caller has no serving clock to share."""

    def now(self) -> float:
        return time.monotonic()


class NullTracer:
    """Disabled tracer: the full API as no-ops.

    Kept method-for-method identical to :class:`Tracer` so call sites
    never branch on "is tracing on" — they just call.  ``enabled`` lets
    the rare hot path that would *build* expensive args skip them."""

    enabled = False

    def new_track(self, label: str) -> int:
        return 0

    def begin(self, tid: int, name: str, **args) -> None:
        pass

    def end(self, tid: int, name: Optional[str] = None, **args) -> None:
        pass

    def instant(self, name: str, tid: int = 0, **args) -> None:
        pass

    @contextmanager
    def span(self, tid: int, name: str, **args):
        yield

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": []}

    def save(self, path: str) -> None:
        raise ValueError("cannot save a NullTracer trace — construct a "
                         "real Tracer to record one")


#: the shared disabled tracer — engine/store/batcher default to this
NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer.  One instance per serve session.

    Track 0 ("engine") always exists and carries scheduler-level instant
    events; :meth:`new_track` allocates one track per in-flight batch
    (the engine does this at launch).  Events store as flat tuples
    ``(ph, t_seconds, tid, name, args_or_None)`` — export converts to
    Chrome trace-event dicts with microsecond timestamps."""

    enabled = True

    def __init__(self, clock=None, *, process: str = "repro.serve"):
        self.clock = clock if clock is not None else _MonotonicClock()
        self.process = process
        self._events: List[Tuple[str, float, int, str, Optional[dict]]] = []
        self._tracks: Dict[int, str] = {0: "engine"}
        self._open: Dict[int, List[str]] = {}
        self._next_tid = 1

    # -- recording -----------------------------------------------------------

    def new_track(self, label: str) -> int:
        tid = self._next_tid
        self._next_tid += 1
        self._tracks[tid] = str(label)
        return tid

    def begin(self, tid: int, name: str, **args) -> None:
        self._events.append(("B", self.clock.now(), tid, name,
                             args or None))
        self._open.setdefault(tid, []).append(name)

    def end(self, tid: int, name: Optional[str] = None, **args) -> None:
        stack = self._open.get(tid)
        if not stack:
            raise ValueError(f"end() on track {tid} with no open span")
        top = stack.pop()
        if name is not None and name != top:
            stack.append(top)
            raise ValueError(f"end({name!r}) on track {tid} but the open "
                             f"span is {top!r}")
        self._events.append(("E", self.clock.now(), tid, top, args or None))

    def instant(self, name: str, tid: int = 0, **args) -> None:
        self._events.append(("i", self.clock.now(), tid, name,
                             args or None))

    @contextmanager
    def span(self, tid: int, name: str, **args):
        self.begin(tid, name, **args)
        try:
            yield
        finally:
            self.end(tid, name)

    # -- export --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    def open_spans(self) -> Dict[int, Tuple[str, ...]]:
        """Still-open spans per track — non-empty means an export now
        would fail pair validation (runs still in flight)."""
        return {tid: tuple(stack) for tid, stack in self._open.items()
                if stack}

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object: thread-name metadata per
        track, then the recorded events with ``ts`` in microseconds."""
        events: List[Dict[str, Any]] = []
        for tid, label in sorted(self._tracks.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tid, "args": {"name": label}})
        for ph, t, tid, name, args in self._events:
            ev: Dict[str, Any] = {"ph": ph, "ts": t * 1e6, "pid": 1,
                                  "tid": tid, "name": name}
            if ph == "i":
                ev["s"] = "t"                 # thread-scoped instant
            if args:
                ev["args"] = args
            events.append(ev)
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"process": self.process}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
        return path


def validate_chrome_trace(obj: Dict[str, Any]) -> int:
    """Structural validation of an exported trace: per track, timestamps
    must be monotonically non-decreasing and every ``B`` matched by an
    ``E`` (no dangling spans, no stray ends).  Returns the number of
    non-metadata events checked; raises ``ValueError`` on violation —
    the benchmark asserts this on the JSON it uploads."""
    last_ts: Dict[int, float] = {}
    stacks: Dict[int, List[str]] = {}
    checked = 0
    for ev in obj.get("traceEvents", []):
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph not in ("B", "E", "i"):
            raise ValueError(f"unsupported event phase {ph!r}")
        tid = ev.get("tid")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            raise ValueError(f"event {ev.get('name')!r} has no numeric ts")
        if tid in last_ts and ts < last_ts[tid]:
            raise ValueError(
                f"track {tid}: ts went backwards ({last_ts[tid]} -> {ts} "
                f"at {ev.get('name')!r})")
        last_ts[tid] = ts
        if ph == "B":
            stacks.setdefault(tid, []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.get(tid)
            if not stack:
                raise ValueError(f"track {tid}: E {ev.get('name')!r} "
                                 "without an open B")
            top = stack.pop()
            if ev.get("name") not in (None, top):
                raise ValueError(f"track {tid}: E {ev.get('name')!r} "
                                 f"closes B {top!r}")
        checked += 1
    dangling = {tid: s for tid, s in stacks.items() if s}
    if dangling:
        raise ValueError(f"unclosed spans at end of trace: {dangling}")
    return checked
