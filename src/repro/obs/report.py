"""Per-request cache-decision explainers.

A :class:`CacheReport` answers, for ONE served request, the question the
aggregate metrics can't: *which steps did the cache actually skip for
me, what did the proxy signal look like against τ, and how much compute
did I really pay?*  The serving engine builds one per request at batch
finish (``telemetry=True``) from whatever the run state recorded:

* **fused adaptive** runs carry the full per-row desired-skip trace —
  and, with step telemetry on, the per-row proxy values — inside the
  on-device loop carry, so the report is exact per row and costs one
  device read at the finish boundary (``host_sync_count`` stays 0);
* **host-dispatched adaptive** runs record the realized (batch-AND)
  decisions only — desired == realized in their reports;
* **static** entries derive the report from the schedule (every row
  identical, by construction).

Step 0's proxy is reported as ``None``: the fused loop's previous-input
buffer is zeros before the first step, so the raw value is meaningless
(the decision rule force-computes step 0 for the same reason).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class CacheReport:
    """Cache behavior of one request (one batch row) over its run.

    ``desired[s]`` is the skip set this row's own accumulator state
    wanted at step ``s``; ``realized[s]`` is the skip set the batch
    executed (the AND over co-batched rows — ``desired`` minus what a
    conservative neighbor forced to compute).  ``proxy[s]`` is the row's
    relative-L1 change signal when step telemetry recorded it."""
    tau: float
    types: Tuple[str, ...]
    desired: Tuple[Tuple[str, ...], ...]
    realized: Tuple[Tuple[str, ...], ...]
    proxy: Optional[Tuple[Optional[float], ...]] = None

    @property
    def num_steps(self) -> int:
        return len(self.realized)

    def skipped_per_type(self) -> Dict[str, int]:
        """Executed (realized) skip count per layer type."""
        out = {t: 0 for t in self.types}
        for skips in self.realized:
            for t in skips:
                out[t] = out.get(t, 0) + 1
        return out

    def desired_per_type(self) -> Dict[str, int]:
        out = {t: 0 for t in self.types}
        for skips in self.desired:
            for t in skips:
                out[t] = out.get(t, 0) + 1
        return out

    def realized_compute_fraction(self) -> float:
        """Fraction of this row's (step × type) layer evaluations that
        actually ran."""
        total = self.num_steps * len(self.types)
        if total == 0:
            return 1.0
        skipped = sum(len(s) for s in self.realized)
        return 1.0 - skipped / float(total)

    def proxy_vs_threshold(self) -> List[Dict]:
        """Per-step trajectory rows ``{step, proxy, desired, realized}``
        for plotting the signal against ``tau``."""
        out = []
        for s in range(self.num_steps):
            out.append({
                "step": s,
                "proxy": None if self.proxy is None else self.proxy[s],
                "desired": list(self.desired[s]),
                "realized": list(self.realized[s]),
            })
        return out

    def to_jsonable(self) -> Dict:
        return {
            "tau": self.tau,
            "types": list(self.types),
            "num_steps": self.num_steps,
            "skipped_per_type": self.skipped_per_type(),
            "desired_per_type": self.desired_per_type(),
            "realized_compute_fraction": self.realized_compute_fraction(),
            "trajectory": self.proxy_vs_threshold(),
        }


def _sig(types: Tuple[str, ...], row) -> Tuple[str, ...]:
    return tuple(t for t, bit in zip(types, row) if bool(bit))


def fused_cache_reports(rs) -> List["CacheReport"]:
    """Exact per-row reports from a fused run's on-device trace — ONE
    boundary device read of the packed (S, B, T) bool trace (plus the
    (S, B) proxy trace when step telemetry was on), never a per-step
    sync."""
    import jax
    import numpy as np
    bits = np.asarray(jax.device_get(rs.trace))[: rs.step]   # (S, B, T)
    types = tuple(rs.pool_types)
    realized = tuple(_sig(types, row)
                     for row in bits.all(axis=1))            # AND over rows
    proxy_rows = None
    if getattr(rs, "proxy_trace", None) is not None:
        proxy_rows = np.asarray(jax.device_get(rs.proxy_trace))[: rs.step]
    out = []
    for b in range(bits.shape[1] if bits.ndim == 3 else 0):
        desired = tuple(_sig(types, bits[s, b])
                        for s in range(bits.shape[0]))
        proxy = None
        if proxy_rows is not None:
            proxy = tuple(None if s == 0 else float(proxy_rows[s, b])
                          for s in range(proxy_rows.shape[0]))
        out.append(CacheReport(tau=float(rs.tau), types=types,
                               desired=desired, realized=realized,
                               proxy=proxy))
    return out


def schedule_cache_report(schedule, tau: float = 0.0) -> "CacheReport":
    """Static entry: the schedule IS the decision record, identical for
    every row."""
    types = tuple(sorted(schedule.skip))
    decisions = tuple(
        tuple(t for t in types if schedule.skip[t][s])
        for s in range(schedule.num_steps))
    return CacheReport(tau=float(tau), types=types, desired=decisions,
                       realized=decisions)


def run_cache_reports(rs, batch: int, schedule=None,
                      tau: float = 0.0) -> List["CacheReport"]:
    """Best-effort reports for any run-state kind (the engine's single
    entry point).  Fused states yield exact per-row reports; states that
    only expose realized ``decisions`` (host adaptive loop, fakes) yield
    desired == realized; static runs fall back to the schedule.  Returns
    ``[]`` when nothing is reconstructible."""
    if getattr(rs, "trace", None) is not None \
            and hasattr(rs, "pool_types"):
        return fused_cache_reports(rs)
    decisions = getattr(rs, "decisions", None)
    if decisions:
        types = tuple(getattr(rs, "pool_types", None)
                      or (sorted(schedule.skip) if schedule is not None
                          else sorted({t for d in decisions for t in d})))
        realized = tuple(tuple(d) for d in decisions)
        rep = CacheReport(tau=float(getattr(rs, "tau", tau)), types=types,
                          desired=realized, realized=realized)
        return [rep] * batch
    if schedule is not None:
        return [schedule_cache_report(schedule, tau)] * batch
    return []
