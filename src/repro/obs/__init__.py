"""repro.obs — structured observability for the serving stack.

Three pieces, woven through executor / engine / batcher / store / SLO /
resilience:

* :class:`Tracer` — span/event recording of the full batch lifecycle
  (submit → admission → formation → join/regroup/coalesce/split-retry →
  advances → finish/shed/fault), exported as Chrome trace-event JSON
  (:meth:`Tracer.save`) loadable in Perfetto.  Disabled is the shared
  :data:`NULL_TRACER` — empty methods, zero storage.
* :class:`MetricsRegistry` — named counters / gauges / histograms /
  ring-buffer time series behind ``ServerMetrics`` (now a view), with a
  JSON :meth:`~MetricsRegistry.snapshot` and a Prometheus-style
  :meth:`~MetricsRegistry.exposition`.
* :class:`CacheReport` — the per-request cache-decision explainer built
  from the fused loop's on-device decision/proxy traces at finish
  boundaries: zero extra host syncs, exact per row.

Layering: this package imports nothing from ``repro.serve`` /
``repro.slo`` / ``repro.resilience`` — they all import it.
"""
from repro.obs.registry import MetricsRegistry, TimeSeries  # noqa: F401
from repro.obs.report import (  # noqa: F401
    CacheReport, fused_cache_reports, run_cache_reports,
    schedule_cache_report)
from repro.obs.tracer import (  # noqa: F401
    NULL_TRACER, NullTracer, Tracer, validate_chrome_trace)
