"""Named metrics: labeled counters / gauges / histograms + time series.

One registry per serve session replaces the ad-hoc attribute-and-dict
plumbing that ``ServerMetrics`` grew over PRs 4–8: every observation is
a named instrument with optional labels, readable three ways —

* :meth:`MetricsRegistry.snapshot` — one JSON-safe dict (what
  ``ServerMetrics.report()`` builds its view from);
* :meth:`MetricsRegistry.exposition` — Prometheus-style text, so a
  deployment can expose the session state on a ``/metrics``-shaped
  endpoint without new plumbing;
* ring-buffer :class:`TimeSeries` for controller trajectories (p95
  wait, active rung, backlog estimate) — bounded memory, newest-N
  retained, the thing a dashboard plots.

Samples are validated at the door: a NaN/inf observation raises
immediately (with the instrument name) instead of silently poisoning a
percentile later — the serving layer's distributions all come through
here.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple


def _require_finite(value: float, where: str) -> float:
    v = float(value)
    if not math.isfinite(v):
        raise ValueError(f"{where}: non-finite sample {value!r} — metrics "
                         "reject NaN/inf at observation time")
    return v


def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class TimeSeries:
    """Bounded ``(t, value)`` ring buffer (newest ``capacity`` points)."""

    def __init__(self, name: str, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = int(capacity)
        self._buf: Deque[Tuple[float, float]] = deque(maxlen=self.capacity)

    def record(self, t: float, value: float) -> None:
        self._buf.append((_require_finite(t, f"series {self.name!r} time"),
                          _require_finite(value, f"series {self.name!r}")))

    def items(self) -> List[Tuple[float, float]]:
        return list(self._buf)

    def last(self) -> Optional[Tuple[float, float]]:
        return self._buf[-1] if self._buf else None

    def __len__(self) -> int:
        return len(self._buf)


class MetricsRegistry:
    """Counters, gauges, histograms (raw samples), and time series."""

    def __init__(self):
        self._counters: Dict[str, Dict[tuple, float]] = {}
        self._gauges: Dict[str, Dict[tuple, float]] = {}
        self._hists: Dict[str, Dict[tuple, List[float]]] = {}
        self._series: Dict[str, TimeSeries] = {}

    # -- writes --------------------------------------------------------------

    def inc(self, name: str, n: float = 1, **labels) -> None:
        _require_finite(n, f"counter {name!r}")
        key = _label_key(labels)
        slot = self._counters.setdefault(name, {})
        slot[key] = slot.get(key, 0) + n

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self._gauges.setdefault(name, {})[_label_key(labels)] = \
            _require_finite(value, f"gauge {name!r}")

    def observe(self, name: str, value: float, **labels) -> None:
        self._hists.setdefault(name, {}).setdefault(
            _label_key(labels), []).append(
                _require_finite(value, f"histogram {name!r}"))

    def series(self, name: str, capacity: int = 256) -> TimeSeries:
        """Get-or-create the named time series (capacity applies on
        creation only)."""
        if name not in self._series:
            self._series[name] = TimeSeries(name, capacity)
        return self._series[name]

    # -- reads ---------------------------------------------------------------

    def counter(self, name: str, **labels) -> float:
        return self._counters.get(name, {}).get(_label_key(labels), 0)

    def counter_total(self, name: str) -> float:
        return sum(self._counters.get(name, {}).values())

    def labeled(self, name: str, label: str) -> Dict[str, float]:
        """A single-label counter as ``{label_value: total}`` — the shape
        the old ``ServerMetrics`` dict attributes had."""
        out: Dict[str, float] = {}
        for key, v in self._counters.get(name, {}).items():
            d = dict(key)
            if label in d:
                out[d[label]] = out.get(d[label], 0) + v
        return out

    def gauge(self, name: str, **labels) -> Optional[float]:
        return self._gauges.get(name, {}).get(_label_key(labels))

    def samples(self, name: str, **labels) -> List[float]:
        if labels:
            return list(self._hists.get(name, {}).get(_label_key(labels),
                                                      []))
        out: List[float] = []
        for xs in self._hists.get(name, {}).values():
            out.extend(xs)
        return out

    def names(self) -> Dict[str, List[str]]:
        return {"counters": sorted(self._counters),
                "gauges": sorted(self._gauges),
                "histograms": sorted(self._hists),
                "series": sorted(self._series)}

    # -- export --------------------------------------------------------------

    @staticmethod
    def _labels_str(key: tuple) -> str:
        if not key:
            return ""
        inner = ",".join(f'{k}="{v}"' for k, v in key)
        return "{" + inner + "}"

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-safe dict of everything: counters/gauges keyed by
        ``name{label="v"}``, histograms summarized, series as point
        lists."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}, "series": {}}
        for name, slots in sorted(self._counters.items()):
            for key, v in sorted(slots.items()):
                out["counters"][name + self._labels_str(key)] = v
        for name, slots in sorted(self._gauges.items()):
            for key, v in sorted(slots.items()):
                out["gauges"][name + self._labels_str(key)] = v
        for name, slots in sorted(self._hists.items()):
            for key, xs in sorted(slots.items()):
                s = sorted(xs)
                out["histograms"][name + self._labels_str(key)] = {
                    "n": len(s), "sum": sum(s),
                    "min": s[0] if s else None,
                    "max": s[-1] if s else None,
                }
        for name, ts in sorted(self._series.items()):
            out["series"][name] = [[t, v] for t, v in ts.items()]
        return out

    def exposition(self) -> str:
        """Prometheus-style text: ``# TYPE`` lines, then one sample line
        per (name, label set).  Histograms expose ``_count``/``_sum``;
        series expose their latest value as a gauge."""
        lines: List[str] = []
        for name, slots in sorted(self._counters.items()):
            lines.append(f"# TYPE {name} counter")
            for key, v in sorted(slots.items()):
                lines.append(f"{name}{self._labels_str(key)} {v:g}")
        for name, slots in sorted(self._gauges.items()):
            lines.append(f"# TYPE {name} gauge")
            for key, v in sorted(slots.items()):
                lines.append(f"{name}{self._labels_str(key)} {v:g}")
        for name, slots in sorted(self._hists.items()):
            lines.append(f"# TYPE {name} summary")
            for key, xs in sorted(slots.items()):
                ls = self._labels_str(key)
                lines.append(f"{name}_count{ls} {len(xs)}")
                lines.append(f"{name}_sum{ls} {sum(xs):g}")
        for name, ts in sorted(self._series.items()):
            last = ts.last()
            if last is not None:
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {last[1]:g}")
        return "\n".join(lines) + "\n"
